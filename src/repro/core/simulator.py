"""Serverless training simulator — paper-faithful MLLess execution model.

Runs P worker replicas *simultaneously* as a vmapped multi-worker step
(leading worker axis on params / optimizer state / consistency state), with:

* divergent local replicas + BSP/SSP/ISP exchange semantics (core.consistency)
* a timing model: per-step worker time = minibatch fetch (COS) + compute
  (flops / worker rate, with lognormal straggler jitter) + exchange
  (Redis round-trips + wire bytes, from ``core.billing.CommModel``)
* FaaS sub-second billing per live worker, plus the always-on VMs
* scale-in auto-tuner integration: evicted workers are masked inert (static
  shapes stay jit-friendly), their replica reintegrated by model averaging
* serverful baseline mode (ring all-reduce, IaaS billing, dense exchange) and
  non-specialized serverless mode (object-storage exchange) — the paper's
  PyTorch and PyWren-IBM comparators.

Wall-clock in the simulator is *modelled* time, not host time: the paper's
claims are about the FaaS/IaaS cost-time trade-off, which depends only on the
modelled rates (documented in DESIGN.md §8). Convergence, however, is REAL:
losses come from actually training the model, so time-to-loss comparisons
combine genuine optimization traces with the platform timing model.
"""

from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotuner as autotuner_lib
from repro.core import billing as billing_lib
from repro.core import consistency as cons_lib
from repro.core import isp as isp_lib
from repro.optim import Optimizer, apply_updates
from repro.wire import codec as wire_codec

PyTree = Any


class Platform(enum.Enum):
    MLLESS = "mlless"  # specialized serverless: Redis exchange, FaaS billing
    SERVERFUL = "serverful"  # PyTorch-like: ring all-reduce, IaaS billing
    PYWREN = "pywren"  # non-specialized serverless: COS-mediated exchange


@dataclasses.dataclass(frozen=True)
class SimulatorConfig:
    n_workers: int
    consistency: cons_lib.ConsistencyConfig = dataclasses.field(
        default_factory=cons_lib.ConsistencyConfig
    )
    platform: Platform = Platform.MLLESS
    comm: billing_lib.CommModel = dataclasses.field(
        default_factory=billing_lib.CommModel
    )
    # compute model: 1 vCPU sustained flops for the Cython/MKL inner loops
    worker_flops_rate: float = 4e9
    straggler_sigma: float = 0.12  # lognormal sigma on per-worker compute time
    # update-store shards (paper: Redis instances). The live runtime's
    # analogue is FaaSJobConfig.n_brokers — calibration runs must set
    # n_redis == n_brokers so the modelled exchange strain AND the billed
    # infra VMs match the topology that actually ran (DESIGN.md §11)
    n_redis: int = 1
    seed: int = 0
    # sparse models update only touched coordinates; serverful exchanges dense
    sparse_model: bool = False
    # repro.wire codec the modelled platform ships updates with — the SAME
    # sizing formula the live runtime's encoder asserts against, so the
    # predicted bytes are the measured bytes at equal nnz (DESIGN.md §10)
    wire_scheme: str = "sparse"
    # FaaS invocation cold start (runtime init: interpreter + framework
    # import + state restore), billed per invocation and stalling the pool
    # once per invocation round — a synchronous pool blocks at the ISP
    # barrier while a respawned worker initializes.  0.0 = legacy model
    # (cold starts ignored); the live calibration (fig6 --live) sets the
    # solo-measured init constant of the local substrate.
    cold_start_s: float = 0.0
    invocations_per_worker: int = 1
    eval_every: int = 1
    # injected intermittent straggler (mirrors FaaSJobConfig.straggler):
    # worker `straggler_worker` takes an extra `straggler_delay_s` on every
    # `straggler_every`-th step.  Off by default — the lognormal jitter
    # above stays the only timing noise, so existing traces are unchanged.
    straggler_worker: Optional[int] = None
    straggler_delay_s: float = 0.0
    straggler_every: int = 1


@dataclasses.dataclass
class StepRecord:
    step: int
    loss: float
    wall_s: float  # modelled wall-clock of this step
    comm_bytes: float
    active_workers: int
    comm_fraction: float  # ISP: fraction of params communicated


@dataclasses.dataclass
class SimResult:
    records: list[StepRecord]
    bill: billing_lib.FaaSBill | None
    iaas_cost: float | None
    total_wall_s: float
    final_loss: float
    converged_at_s: Optional[float]
    converged_at_step: Optional[int]
    worker_lifetimes_s: list[float]
    summary: dict

    @property
    def total_cost(self) -> float:
        if self.bill is not None:
            return self.bill.total
        return float(self.iaas_cost or 0.0)

    def perf_per_dollar(self) -> float:
        t = self.converged_at_s or self.total_wall_s
        return billing_lib.perf_per_dollar(t, self.total_cost)


class ServerlessSimulator:
    """One training job on a modelled platform.

    Args:
      config: platform/timing configuration.
      grad_fn: ``(params, batch) -> (loss, grads)`` for ONE worker.
      optimizer: a ``repro.optim.Optimizer``.
      params: initial model parameters (single replica; will be stacked).
      flops_per_sample: compute cost model for one sample's grad+update.
      update_nnz_fn: optional ``(grads) -> nnz`` for sparse update sizing;
        defaults to full parameter count (dense).
    """

    def __init__(
        self,
        config: SimulatorConfig,
        grad_fn: Callable[[PyTree, Any], tuple[jax.Array, PyTree]],
        optimizer: Optimizer,
        params: PyTree,
        flops_per_sample: float,
        update_nnz_fn: Optional[Callable[[PyTree], jax.Array]] = None,
    ):
        self.config = config
        self.grad_fn = grad_fn
        self.optimizer = optimizer
        P = config.n_workers
        self.n_params = int(
            sum(x.size for x in jax.tree.leaves(params))
        )
        # stack replicas: every worker starts from the same point (paper's
        # sanity check §6.1 — identical convergence at fixed seed)
        self.replicas = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (P,) + x.shape), params
        )
        self.opt_state = jax.vmap(optimizer.init)(self.replicas)
        self.flops_per_sample = float(flops_per_sample)
        self.update_nnz_fn = update_nnz_fn
        # consistency state
        cc = config.consistency
        self.isp_state = cons_lib.isp_init(self.replicas)
        self.ssp_state = cons_lib.ssp_init(self.replicas, max(cc.slack, 1))
        self.active = np.ones(P, dtype=bool)
        self._rng = np.random.default_rng(config.seed)
        self._lifetimes = np.zeros(P, dtype=np.float64)
        self._wall = 0.0
        # SSP pipeline clocks (DESIGN.md §13 priced): per-worker finish
        # times, the per-step "all stored" gate, and the pool frontier
        self._ssp_finish = np.zeros(P, dtype=np.float64)
        self._ssp_gate: dict[int, float] = {}
        self._ssp_front = 0.0
        self._jit_step = jax.jit(self._multi_worker_step)

    # -- the jitted multi-worker step -----------------------------------------

    def _multi_worker_step(self, replicas, opt_state, isp_state, ssp_state,
                           batch_stacked, active_mask):
        cfg = self.config
        cc = cfg.consistency

        def one_worker(params, ost, batch):
            loss, grads = self.grad_fn(params, batch)
            updates, ost2 = self.optimizer.update(grads, ost, params)
            return loss, updates, ost2

        losses, updates, opt_state2 = jax.vmap(one_worker)(
            replicas, opt_state, batch_stacked
        )
        amask = active_mask.astype(losses.dtype)
        # inert evicted workers: zero update contribution. Active workers'
        # updates are scaled 1/P_active BEFORE exchange: the paper averages
        # local gradients into the global update (§3.2), so summing the
        # exchanged parts must reconstruct the average — without this the
        # effective step size grows with P and constant-B_g scaling
        # (Table 3) loses its statistical-efficiency invariance.
        p_active = jnp.maximum(jnp.sum(amask), 1.0)
        updates = jax.tree.map(
            lambda u: u * amask.reshape((-1,) + (1,) * (u.ndim - 1))
            / p_active,
            updates,
        )

        comm_frac = jnp.asarray(1.0, jnp.float32)
        if cc.model is cons_lib.Model.ISP:
            visible, isp_state, masks = cons_lib.isp_exchange(
                cc.isp, isp_state, updates, replicas
            )
            # fraction of ACTIVE workers' parameters communicated
            total = sum(m.size for m in jax.tree.leaves(masks))
            hits = sum(
                jnp.sum(m.astype(jnp.float32)) for m in jax.tree.leaves(masks)
            )
            comm_frac = hits / total
        elif cc.model is cons_lib.Model.SSP:
            visible, ssp_state = cons_lib.ssp_step(ssp_state, updates)
        else:  # BSP
            visible = cons_lib.bsp_exchange(updates)

        replicas2 = apply_updates(replicas, visible)
        # evicted workers' replicas frozen
        replicas2 = jax.tree.map(
            lambda new, old: jnp.where(
                active_mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old
            ),
            replicas2,
            replicas,
        )
        mean_loss = jnp.sum(losses * amask) / jnp.maximum(jnp.sum(amask), 1.0)
        return replicas2, opt_state2, isp_state, ssp_state, mean_loss, comm_frac

    # -- timing + billing ------------------------------------------------------

    def _step_times(self, batch_size: int, comm_bytes_per_worker: float,
                    p_active: int, step: int) -> tuple[float, np.ndarray]:
        """Returns (wall_s, per-worker busy seconds) for one step."""
        cfg = self.config
        compute = self.flops_per_sample * batch_size / cfg.worker_flops_rate
        jitter = self._rng.lognormal(0.0, cfg.straggler_sigma, size=p_active)
        per_worker_compute = compute * jitter
        active_ids = np.nonzero(self.active)[0]
        if (
            cfg.straggler_worker is not None
            and step % max(cfg.straggler_every, 1) == 0
        ):
            hit = np.nonzero(active_ids == cfg.straggler_worker)[0]
            per_worker_compute[hit] += cfg.straggler_delay_s
        fetch = cfg.comm.cos_fetch_s
        if cfg.platform is Platform.SERVERFUL:
            comm = cfg.comm.allreduce_time(comm_bytes_per_worker, p_active)
        elif cfg.platform is Platform.PYWREN:
            # COS-mediated exchange: object-store latency per push/pull
            slow = billing_lib.CommModel(
                redis_rtt_s=cfg.comm.cos_fetch_s,
                redis_bw_Bps=cfg.comm.redis_bw_Bps / 2,
                cos_fetch_s=cfg.comm.cos_fetch_s,
            )
            comm = slow.indirect_exchange_time(
                comm_bytes_per_worker, p_active, 1
            )
        else:
            comm = cfg.comm.indirect_exchange_time(
                comm_bytes_per_worker, p_active, cfg.n_redis
            )
        busy = fetch + per_worker_compute + comm
        cc = self.config.consistency
        if cfg.platform is not Platform.MLLESS or cc.model in (
            cons_lib.Model.BSP,
            cons_lib.Model.ISP,
        ):
            wall = float(np.max(busy))  # synchronous barrier
        else:
            # SSP: the bounded-staleness pipeline the live broker enforces
            # (DESIGN.md §13).  A worker starts step t once it finished
            # t-1 AND every worker has stored step t-slack-1 (the gate its
            # pull at t waits on); the pool frontier advances at the pace
            # of that pipeline, so a hiccup shorter than the accumulated
            # slack lead costs nothing while a persistent laggard drags
            # the gates — exactly the live tail behaviour.
            gate = self._ssp_gate.get(step - cc.slack - 1, 0.0)
            start = np.maximum(self._ssp_finish[active_ids], gate)
            finish = start + busy
            self._ssp_finish[active_ids] = finish
            self._ssp_gate[step] = float(np.max(finish))
            front = float(np.max(self._ssp_finish[active_ids]))
            wall = front - self._ssp_front
            self._ssp_front = front
        return wall, busy

    # -- update sizing ---------------------------------------------------------

    def _bytes_out(self, comm_frac: float, batch_size: int) -> float:
        """Per-worker bytes pushed this step under the platform's encoding.

        Reads the byte size from the shared wire codec
        (``repro.wire.codec.leaf_nbytes``) — the function the live
        runtime's encoder asserts its output length against — instead of
        a hand-rolled formula that could drift from what the runtime
        actually ships.

        Granularity caveat: the simulator sizes the WHOLE model as one
        fp32 leaf with an aggregate nnz.  For a fixed ``sparse`` scheme
        on sub-2**31-param models this equals the per-leaf sum exactly;
        ``bitmap`` is exact up to per-leaf mask rounding (< 1 byte per
        leaf) and ``auto`` is a lower bound (the live encoder picks the
        cheapest codec PER LEAF).  The exact per-leaf invariant lives in
        ``repro.wire.predict_tree_nbytes`` and is what the cross-check
        tests assert.
        """
        cfg = self.config
        if cfg.platform is Platform.SERVERFUL:
            # dense ring all-reduce of the full gradient
            return float(billing_lib.dense_update_bytes(self.n_params))
        nnz = float(self.n_params)
        if cfg.sparse_model and self.update_nnz_fn is not None:
            nnz = float(self.update_nnz_fn(batch_size))
        if cfg.consistency.model is cons_lib.Model.ISP:
            nnz = nnz * max(comm_frac, 0.0)
        if cfg.wire_scheme == wire_codec.AUTO:
            return float(min(
                wire_codec.leaf_nbytes(s, self.n_params, nnz)
                for s in wire_codec.SCHEMES
            ))
        return float(
            wire_codec.leaf_nbytes(cfg.wire_scheme, self.n_params, nnz)
        )

    # -- driver -----------------------------------------------------------------

    def run(
        self,
        batch_fn: Callable[[int, int], Any],
        batch_size: int,
        max_steps: int,
        loss_threshold: Optional[float] = None,
        eval_fn: Optional[Callable[[PyTree], float]] = None,
        tuner: Optional[autotuner_lib.ScaleInAutoTuner] = None,
    ) -> SimResult:
        """Run until convergence or max_steps.

        Args:
          batch_fn: ``(step, n_workers) -> batch pytree stacked (P, B, ...)``.
            Always called with the FULL P (evicted workers' slices are inert).
          batch_size: per-worker minibatch size B (weak scaling: fixed).
          loss_threshold: stop when eval loss <= threshold (paper's metric).
          eval_fn: replica -> scalar eval loss; defaults to training loss.
          tuner: optional scale-in auto-tuner (MLLess platform only).
        """
        cfg = self.config
        P = cfg.n_workers
        records: list[StepRecord] = []
        converged_at = None
        converged_step = None
        # cold-start accounting: invocation boundaries fall every
        # steps_per_inv steps, and a worker only bills the cold starts of
        # invocations it actually began (evicted workers stop)
        steps_per_inv = max(
            -(-max_steps // max(cfg.invocations_per_worker, 1)), 1
        )
        active_steps = np.zeros(P, dtype=np.int64)

        for step in range(1, max_steps + 1):
            batch = batch_fn(step, P)
            (
                self.replicas,
                self.opt_state,
                self.isp_state,
                self.ssp_state,
                loss,
                comm_frac,
            ) = self._jit_step(
                self.replicas,
                self.opt_state,
                self.isp_state,
                self.ssp_state,
                batch,
                jnp.asarray(self.active),
            )
            loss = float(loss)
            comm_frac = float(comm_frac)
            p_active = int(self.active.sum())
            bytes_out = self._bytes_out(comm_frac, batch_size)
            wall, busy = self._step_times(batch_size, bytes_out, p_active,
                                          step)
            self._wall += wall
            self._lifetimes[self.active] += busy
            active_steps[self.active] += 1

            eval_loss = loss
            if eval_fn is not None and step % cfg.eval_every == 0:
                replica0 = jax.tree.map(lambda x: x[0], self.replicas)
                eval_loss = float(eval_fn(replica0))

            records.append(
                StepRecord(step, eval_loss, wall, bytes_out * p_active,
                           p_active, comm_frac)
            )

            if tuner is not None and cfg.platform is Platform.MLLESS:
                tuner.observe(step, eval_loss, wall)
                decision = tuner.decide()
                if decision.remove_worker and p_active > 1:
                    self._evict_one()

            if loss_threshold is not None and eval_loss <= loss_threshold:
                converged_at = self._wall
                converged_step = step
                break

        # billing (cold starts: each invocation a worker actually began
        # bills its runtime init, and each invocation round the pool ran
        # through stalls the synchronous barrier once — the per-step time
        # model above stays pure step time)
        inv_per_worker = np.maximum(
            np.ceil(active_steps / steps_per_inv), active_steps > 0
        )
        rounds_executed = int(-(-len(records) // steps_per_inv))
        bill_wall = self._wall + cfg.cold_start_s * rounds_executed
        if cfg.platform is Platform.SERVERFUL:
            bill = None
            iaas = billing_lib.iaas_cost(P, self._wall)
        else:
            bill = billing_lib.faas_cost(
                [
                    t + cfg.cold_start_s * float(k)
                    for t, k in zip(self._lifetimes, inv_per_worker)
                ],
                bill_wall,
                cfg.n_redis,
            )
            iaas = None

        return SimResult(
            records=records,
            bill=bill,
            iaas_cost=iaas,
            total_wall_s=self._wall,
            final_loss=records[-1].loss if records else float("nan"),
            converged_at_s=converged_at,
            converged_at_step=converged_step,
            worker_lifetimes_s=list(self._lifetimes),
            summary={
                "platform": cfg.platform.value,
                "consistency": cfg.consistency.model.value,
                "final_workers": int(self.active.sum()),
            },
        )

    # -- eviction ----------------------------------------------------------------

    def _evict_one(self) -> None:
        """Evict the lowest-quality active replica (highest local loss proxy:
        largest residual norm; falls back to highest index) and reintegrate
        its replica by model averaging (paper §4.2 eviction policy)."""
        active_ids = np.nonzero(self.active)[0]
        if active_ids.size <= 1:
            return
        evicted = int(active_ids[-1])
        if self.config.consistency.model is cons_lib.Model.ISP:
            # flush: average the leaving replica into the remaining ones
            new_active = self.active.copy()
            new_active[evicted] = False
            self.replicas = autotuner_lib.evict_and_reintegrate(
                self.replicas, evicted, jnp.asarray(new_active)
            )
            self.active = new_active
        else:
            self.active[evicted] = False
