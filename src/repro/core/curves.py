"""Loss-curve models and fitting for the scale-in auto-tuner (paper §4.2).

Two curve families, per the paper:

* reference (fast-convergence region, Eq. 2):
      L_P(t) = 1 / (th0 * t^th1 + th2) + th3
* slow-convergence (after worker removals, Eq. 3, from SLAQ):
      l_p(t) = 1 / (th0 * t^2 + th1 * t + th2) + th3

with non-negative coefficients, fitted by non-negative least squares on
EWMA-smoothed loss observations. The paper uses scipy's curve_fit; we
implement a projected-gradient NNLS in numpy so the controller has no scipy
dependency on the hot path (scipy is still used in tests as an oracle when
available).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np


def ewma(values: Sequence[float], alpha: float = 0.3) -> np.ndarray:
    """Exponentially weighted moving average filter (outlier removal)."""
    values = np.asarray(values, dtype=np.float64)
    out = np.empty_like(values)
    acc = values[0]
    for i, v in enumerate(values):
        acc = alpha * v + (1.0 - alpha) * acc
        out[i] = acc
    return out


def reference_curve(t: np.ndarray, th: np.ndarray) -> np.ndarray:
    """Eq. 2: 1/(th0 * t^th1 + th2) + th3."""
    t = np.asarray(t, dtype=np.float64)
    return 1.0 / (th[0] * np.power(np.maximum(t, 1e-9), th[1]) + th[2] + 1e-12) + th[3]


def slow_curve(t: np.ndarray, th: np.ndarray) -> np.ndarray:
    """Eq. 3: 1/(th0 * t^2 + th1 * t + th2) + th3."""
    t = np.asarray(t, dtype=np.float64)
    return 1.0 / (th[0] * t * t + th[1] * t + th[2] + 1e-12) + th[3]


@dataclasses.dataclass
class FittedCurve:
    kind: str  # "reference" | "slow"
    theta: np.ndarray
    rmse: float

    def __call__(self, t) -> np.ndarray:
        fn = reference_curve if self.kind == "reference" else slow_curve
        return fn(np.asarray(t, dtype=np.float64), self.theta)


def _nnls_fit(
    fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
    t: np.ndarray,
    y: np.ndarray,
    theta0: np.ndarray,
    iters: int = 400,
) -> np.ndarray:
    """Projected-gradient least squares with a non-negativity constraint.

    Gauss-Newton-ish: numeric Jacobian, backtracking line search, projection
    onto theta >= 0. Small problems (4 params, <= a few hundred points), so
    an O(iters * n * 4) numeric scheme is plenty.
    """
    theta = np.maximum(np.asarray(theta0, dtype=np.float64), 0.0)
    n = t.size

    def loss(th):
        r = fn(t, th) - y
        return float(np.dot(r, r) / n)

    cur = loss(theta)
    eps = 1e-6
    step = 0.1
    for _ in range(iters):
        # numeric gradient
        g = np.zeros_like(theta)
        for j in range(theta.size):
            th2 = theta.copy()
            th2[j] += eps
            g[j] = (loss(th2) - cur) / eps
        gn = np.linalg.norm(g)
        if gn < 1e-12:
            break
        d = -g / gn
        # backtracking
        improved = False
        s = step
        for _ in range(20):
            cand = np.maximum(theta + s * d, 0.0)
            cl = loss(cand)
            if cl < cur - 1e-15:
                theta, cur = cand, cl
                improved = True
                step = min(s * 1.5, 1.0)
                break
            s *= 0.5
        if not improved:
            step *= 0.5
            if step < 1e-10:
                break
    return theta


def _nnls_linear(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Tiny active-set NNLS: solve min ||A x - b|| s.t. x >= 0.

    Lawson-Hanson on <= 3 columns — exact enough for the curve families.
    """
    n = A.shape[1]
    best_x, best_r = np.zeros(n), float(np.dot(b, b))
    # enumerate support sets (n <= 3 -> at most 8 subsets)
    for mask in range(1, 1 << n):
        cols = [j for j in range(n) if mask >> j & 1]
        sub = A[:, cols]
        sol, *_ = np.linalg.lstsq(sub, b, rcond=None)
        if np.any(sol < 0):
            continue
        r = sub @ sol - b
        rr = float(np.dot(r, r))
        if rr < best_r:
            best_r = rr
            best_x = np.zeros(n)
            best_x[cols] = sol
    return best_x


def _fit_linearized(
    kind: str, t: np.ndarray, y: np.ndarray, basis_fn, assemble,
    n_floor: int = 24
) -> FittedCurve | None:
    """Both paper curves are linear in their denominator coefficients once
    th3 is fixed: 1/(y - th3) = sum_j coef_j * f_j(t). Grid th3 below
    y.min(), solve each by NNLS, keep the best in ORIGINAL loss space.
    ``assemble(coef, th3)`` builds the full theta for the curve family."""
    fn = reference_curve if kind == "reference" else slow_curve
    ymin = float(y.min())
    best = None
    for th3 in np.linspace(0.0, max(ymin - 1e-6, 0.0), n_floor):
        z = y - th3
        if np.any(z <= 1e-9):
            continue
        w = z * z  # weight: d(1/z) errors by z^2 to approximate loss-space LS
        A = basis_fn(t) * w[:, None]
        b = (1.0 / z) * w
        coef = _nnls_linear(A, b)
        th = assemble(coef, th3)
        r = fn(t, th) - y
        rmse = float(np.sqrt(np.mean(r * r)))
        if best is None or rmse < best.rmse:
            best = FittedCurve(kind, th, rmse)
    return best


def fit_reference(t: Sequence[float], y: Sequence[float]) -> FittedCurve:
    """Fit Eq. 2 to (t, y): grid over (exponent th1, floor th3), linear NNLS
    for (th0, th2), then a short projected-gradient polish."""
    t = np.asarray(t, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    best = None
    for th1 in (0.4, 0.5, 0.65, 0.8, 0.9, 1.0, 1.2, 1.5):
        def basis(tt, _e=th1):
            return np.stack([np.power(tt, _e), np.ones_like(tt)], axis=1)

        def assemble(coef, th3, _e=th1):
            return np.array([coef[0], _e, coef[1], th3], dtype=np.float64)

        cand = _fit_linearized("reference", t, y, basis, assemble)
        if cand is not None and (best is None or cand.rmse < best.rmse):
            best = cand
    # polish in full nonlinear form
    th = _nnls_fit(reference_curve, t, y, best.theta, iters=150)
    r = reference_curve(t, th) - y
    rmse = float(np.sqrt(np.mean(r * r)))
    return FittedCurve("reference", th, rmse) if rmse < best.rmse else best


def fit_slow(t: Sequence[float], y: Sequence[float]) -> FittedCurve:
    """Fit Eq. 3 to (t, y): linear NNLS in (th0, th1, th2) per th3 grid."""
    t = np.asarray(t, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)

    def basis(tt):
        return np.stack([tt * tt, tt, np.ones_like(tt)], axis=1)

    def assemble(coef, th3):
        return np.array([coef[0], coef[1], coef[2], th3], dtype=np.float64)

    best = _fit_linearized("slow", t, y, basis, assemble)
    th = _nnls_fit(slow_curve, t, y, best.theta, iters=150)
    r = slow_curve(t, th) - y
    rmse = float(np.sqrt(np.mean(r * r)))
    return FittedCurve("slow", th, rmse) if rmse < best.rmse else best


def detect_knee(losses: Sequence[float], slope_threshold: float = 0.05,
                window: int = 5) -> int | None:
    """Paper's knee heuristic: threshold on the first derivative.

    Returns the first index where the windowed mean |dL/dt|, normalised by the
    initial drop rate, falls below ``slope_threshold``; None if not reached.
    """
    y = np.asarray(losses, dtype=np.float64)
    if y.size < 2 * window + 2:
        return None
    d = np.abs(np.diff(y))
    # windowed slope
    kernel = np.ones(window) / window
    sm = np.convolve(d, kernel, mode="valid")
    ref = max(float(sm[: max(window, 1)].mean()), 1e-12)
    below = np.nonzero(sm / ref < slope_threshold)[0]
    if below.size == 0:
        return None
    return int(below[0] + window)
