"""Consistency models: BSP, SSP, ISP (paper §3.1, §4.1, §6.4).

These define *when a worker may proceed* and *which updates it sees*:

* **BSP** — bulk-synchronous: everyone exchanges everything every step.
* **SSP** — stale-synchronous with slack ``s``: a worker at iteration t is
  guaranteed to have seen all updates from iterations <= t - s - 1; updates
  from (t-s .. t-1) may or may not have arrived. Implemented as a delay queue.
* **ISP** — insignificance-bounded synchronous (the paper's contribution):
  synchronous barrier each step, but each worker only broadcasts its
  significant accumulated updates (see ``core.isp``).

The simulator composes these with the communication cost model to reproduce
the paper's Fig. 7/9 comparisons. All three are expressed as pure functions on
a ``(P, ...)``-leading worker axis so the simulator can ``jit`` the whole
multi-worker step.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import isp as isp_lib

PyTree = Any


class Model(enum.Enum):
    BSP = "bsp"
    SSP = "ssp"
    ISP = "isp"


@dataclasses.dataclass(frozen=True)
class ConsistencyConfig:
    model: Model = Model.BSP
    # ISP
    isp: isp_lib.ISPConfig = dataclasses.field(default_factory=isp_lib.ISPConfig)
    # SSP slack (paper §6.4 uses s = 3)
    slack: int = 3


class SSPState(NamedTuple):
    """Delay-queue state for SSP.

    ``queue`` holds the last ``slack`` steps of per-worker updates that have
    been *produced* but not yet *applied* by every worker; entry ``queue[d]``
    is the update produced ``d+1`` steps ago. Under the paper's guarantee, an
    update produced at step t must be visible by step t + s, so the queue
    drains its oldest slot every step. ``ages`` tracks per-slot occupancy.
    """

    queue: PyTree  # each leaf: (slack, P, *param_shape)
    step: jax.Array


def ssp_init(params_stacked: PyTree, slack: int) -> SSPState:
    """Zero delay queue; leaves of ``params_stacked`` have leading (P, ...)."""
    queue = jax.tree.map(
        lambda p: jnp.zeros((slack,) + p.shape, p.dtype), params_stacked
    )
    return SSPState(queue=queue, step=jnp.asarray(1, jnp.int32))


def ssp_step(
    state: SSPState, updates: PyTree
) -> tuple[PyTree, SSPState]:
    """One SSP exchange.

    Each worker immediately applies its *own* update; remote updates are
    delivered with the maximum permitted staleness (worst case the bound
    allows — the adversarial schedule, which is what makes SSP's convergence
    guarantee meaningful). Returns the pytree of updates *visible* to each
    worker this step (leading axis P) and the new state.
    """

    def leaf(q, u):
        # q: (slack, P, ...); u: (P, ...)
        delivered = q[-1]  # oldest slot: everyone sees it now (sum over workers)
        remote_now = jnp.sum(delivered, axis=0, keepdims=True)  # (1, ...)
        # shift the queue and enqueue this step's updates
        new_q = jnp.concatenate([u[None], q[:-1]], axis=0)
        # Each worker sees its own update instantly; 'delivered' includes each
        # worker's own old update which it already applied, so subtract it.
        visible = u + jnp.broadcast_to(remote_now, u.shape) - delivered
        return visible, new_q

    out = jax.tree.map(leaf, state.queue, updates)
    treedef = jax.tree.structure(updates)
    leaves = treedef.flatten_up_to(out)
    visible = treedef.unflatten([l[0] for l in leaves])
    new_queue = treedef.unflatten([l[1] for l in leaves])
    return visible, SSPState(queue=new_queue, step=state.step + 1)


def ssp_drain(state: SSPState) -> PyTree:
    """Sum of everything still in flight (applied at job end / barrier)."""

    def leaf(q):
        per_worker = jnp.sum(q, axis=0)  # (P, ...)
        total = jnp.sum(per_worker, axis=0, keepdims=True)
        return jnp.broadcast_to(total, per_worker.shape) - per_worker

    return jax.tree.map(leaf, state.queue)


def bsp_exchange(updates: PyTree) -> PyTree:
    """BSP: every worker sees the sum of all updates, immediately.

    ``updates`` leaves have leading worker axis (P, ...); the result is the
    same-shaped pytree where every worker's slice is the global sum.
    """

    def leaf(u):
        total = jnp.sum(u, axis=0, keepdims=True)
        return jnp.broadcast_to(total, u.shape)

    return jax.tree.map(leaf, updates)


class ISPWorkerState(NamedTuple):
    """Per-worker ISP state with leading (P, ...) axes on residual leaves."""

    residual: PyTree
    step: jax.Array


def isp_init(params_stacked: PyTree) -> ISPWorkerState:
    residual = jax.tree.map(jnp.zeros_like, params_stacked)
    return ISPWorkerState(residual=residual, step=jnp.asarray(1, jnp.int32))


def isp_exchange(
    config: isp_lib.ISPConfig,
    state: ISPWorkerState,
    updates: PyTree,
    replicas: PyTree,
) -> tuple[PyTree, ISPWorkerState, PyTree]:
    """One ISP exchange under paper-faithful replica semantics.

    Per worker p: ``acc_p = r_p + u_p`` is split by the significance test
    against that worker's own replica values. Worker p applies its *full*
    ``acc_p`` locally? — no: per the paper each worker applies its own update
    u_p fully and broadcasts only the significant accumulated part. The view
    worker p holds is (Eq. 4): its own local updates plus all *significant*
    updates from others. Equivalently each worker applies::

        visible_p = u_p + sum_{p' != p} sig_{p'}

    while sig_p's emission clears worker p's residual (others have now seen
    it) and the insignificant remainder stays in r_p.

    Returns ``(visible, new_state, masks)`` with leading (P, ...) axes.
    """
    v_t = config.threshold(state.step)

    def leaf(u, x, r):
        acc = r + u  # (P, ...)
        sig, res, mask = isp_lib.significance_split(
            acc, x, v_t, config.absolute_floor
        )
        # Sum of significant updates over all workers, delivered to everyone.
        sig_total = jnp.sum(sig, axis=0, keepdims=True)
        # Worker p sees: own update u_p  +  others' significant parts.
        visible = u + jnp.broadcast_to(sig_total, u.shape) - sig
        # Residual: emitting sig_p also removes it from p's own pending
        # divergence (p has applied acc_p's significant part via broadcast
        # bookkeeping: p applied u_p already; the sig part it emitted covers
        # r_p's significant portion which p had *already applied locally* in
        # earlier steps -> do NOT re-apply to p itself; hence '- sig' above).
        return visible, res, mask

    out = jax.tree.map(leaf, updates, replicas, state.residual)
    treedef = jax.tree.structure(updates)
    leaves = treedef.flatten_up_to(out)
    visible = treedef.unflatten([l[0] for l in leaves])
    res = treedef.unflatten([l[1] for l in leaves])
    masks = treedef.unflatten([l[2] for l in leaves])
    return visible, ISPWorkerState(res, state.step + 1), masks
