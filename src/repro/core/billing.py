"""Cost models — paper Table 2 pricing, FaaS sub-second billing, Perf/$.

The paper's cost comparison (§6.3.2) hinges on two billing regimes:

* **FaaS**: pay-per-usage, billed per 100 ms *per live worker* — so the
  scale-in auto-tuner converts removed workers into immediate savings.
* **IaaS**: reservation-based hourly VM pricing (the paper "conservatively"
  pro-rates it per second, favouring PyTorch; we do the same).

We keep the paper's exact April-2021 us-east prices so its numbers reproduce,
and add TPU-pod chip-second accounting for the pod runtime (v5e on-demand
pricing as the analogous constant).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence


# ---- paper Table 2 (IBM Cloud, us-east, April 2021) -------------------------

FAAS_WORKER_USD_PER_S = 3.4e-5  # Functions, 1 vCPU / 2 GB (0.122 $/h)
FAAS_BILLING_QUANTUM_S = 0.1  # IBM bills per 100 ms
MESSAGING_VM_USD_PER_S = 0.15 / 3600.0  # C1.4x4 hosting RabbitMQ
REDIS_VM_USD_PER_S = 0.17 / 3600.0  # M1.2x16 hosting Redis
PYTORCH_VM_USD_PER_S = 0.2 / 3600.0  # B1.4x8 = four PyTorch workers
PYTORCH_WORKER_USD_PER_S = PYTORCH_VM_USD_PER_S / 4.0  # 0.05 $/h each

# ---- TPU v5e analogue (for the pod runtime's chip-second accounting) --------

TPU_V5E_USD_PER_CHIP_HOUR = 1.20  # on-demand list price analogue
TPU_V5E_USD_PER_CHIP_S = TPU_V5E_USD_PER_CHIP_HOUR / 3600.0


@dataclasses.dataclass(frozen=True)
class FaaSBill:
    """Accumulated cost of a serverless training job."""

    worker_seconds: float  # sum over workers of their individual lifetimes
    wall_seconds: float  # job wall-clock (supervisor + VMs are billed on this)
    # one always-on Redis-analogue VM per update-store shard; live runs
    # pass the real shard count (n_redis == FaaSJobConfig.n_brokers)
    n_redis: int = 1

    @property
    def worker_cost(self) -> float:
        # Per-worker lifetimes are rounded up to the billing quantum.
        return self.worker_seconds * FAAS_WORKER_USD_PER_S

    @property
    def infra_cost(self) -> float:
        return self.wall_seconds * (
            MESSAGING_VM_USD_PER_S + self.n_redis * REDIS_VM_USD_PER_S
        )

    @property
    def total(self) -> float:
        return self.worker_cost + self.infra_cost


def faas_worker_seconds(lifetimes_s: Sequence[float]) -> float:
    """Sum of per-worker lifetimes, each rounded up to the 100 ms quantum."""
    q = FAAS_BILLING_QUANTUM_S
    return float(sum(math.ceil(t / q) * q for t in lifetimes_s))


def faas_cost(lifetimes_s: Sequence[float], wall_s: float, n_redis: int = 1) -> FaaSBill:
    return FaaSBill(
        worker_seconds=faas_worker_seconds(lifetimes_s),
        wall_seconds=wall_s,
        n_redis=n_redis,
    )


def multi_job_rollup(
    lifetimes_s: Sequence[float],
    wall_s: float,
    n_redis: int,
    busy_s_by_job: dict,
) -> dict:
    """Attribute one bin-packed fleet's bill to its jobs (DESIGN.md §14.4).

    The fleet pays ONE pooled bill — quantum-rounded invocation lifetimes
    plus the shared messaging/Redis VMs billed once on the fleet wall
    clock.  Each job is charged its proportional share by measured busy
    seconds (the sum over its telemetry rows of ``dur_s * p_active``: the
    worker-seconds the job actually occupied, which is what a solo run
    would have billed compute for).  Barrier stalls — the seconds NO job
    was computing — are what bin-packing reclaims, and they surface here
    as ``pooled_total < sum(solo totals)``: the pool's idle-share shrinks
    and the infra wall is billed once instead of once per job.

    Returns ``{"bill": FaaSBill, "per_job": {job: {busy_s, share,
    worker_cost, infra_cost, total}}}``; per-job totals sum to the pooled
    total exactly (shares are normalized over measured busy seconds).
    """
    bill = faas_cost(lifetimes_s, wall_s, n_redis=n_redis)
    busy = {j: max(float(b), 0.0) for j, b in busy_s_by_job.items()}
    denom = sum(busy.values())
    per_job = {}
    for j, b in busy.items():
        share = (b / denom) if denom > 0 else 1.0 / max(len(busy), 1)
        per_job[j] = {
            "busy_s": b,
            "share": share,
            "worker_cost": share * bill.worker_cost,
            "infra_cost": share * bill.infra_cost,
            "total": share * bill.total,
        }
    return {"bill": bill, "per_job": per_job}


def iaas_cost(n_workers: int, wall_s: float) -> float:
    """PyTorch-cluster cost: workers come in VMs of four, billed per second
    (the paper's 'conservative' pro-rating), all alive for the whole job."""
    n_vms = math.ceil(n_workers / 4)
    return n_vms * PYTORCH_VM_USD_PER_S * wall_s


def tpu_pod_cost(chip_seconds: float) -> float:
    return chip_seconds * TPU_V5E_USD_PER_CHIP_S


def perf_per_dollar(exec_time_s: float, price_usd: float) -> float:
    """Paper §6.2.2: Perf/$ := 1/exec_time * 1/price. Higher is better."""
    return 1.0 / (max(exec_time_s, 1e-12) * max(price_usd, 1e-12))


# ---- communication cost model (simulator) -----------------------------------
#
# Byte SIZES are not modelled here: they come from the shared wire codec
# (``repro.wire.codec.leaf_nbytes``) — the same formula the live runtime's
# encoder asserts its output against — so the cost model can never charge
# for bytes the runtime wouldn't ship (DESIGN.md §10).


def dense_update_bytes(n_params: int, itemsize: int = 4) -> int:
    """Bytes of a dense full-update exchange (the BSP / all-reduce unit),
    read from the shared wire codec."""
    from repro.wire import codec as wire_codec

    return int(wire_codec.leaf_nbytes("dense", n_params, n_params, itemsize))


@dataclasses.dataclass(frozen=True)
class CommModel:
    """Latency/bandwidth model of the indirect-communication substrate.

    Defaults approximate the paper's measured environment: Redis round trips
    of a few hundred microseconds at ~1 Gbps NICs, object-store minibatch
    fetches of tens of milliseconds. The *serverful* baseline instead uses a
    ring all-reduce over the same NICs (Gloo), whose per-step time for an
    n-float model across P workers is 2(P-1)/P * n*4 bytes / bw + latency.
    """

    redis_rtt_s: float = 1.0e-3  # per push/pull round trip
    redis_bw_Bps: float = 125e6  # 1 Gbps
    cos_fetch_s: float = 30e-3  # minibatch fetch from object storage
    ring_latency_s: float = 0.5e-3
    ring_bw_Bps: float = 125e6

    def indirect_exchange_time(self, bytes_out: float, n_workers: int,
                               n_redis: int = 1) -> float:
        """Push own update + pull (P-1) peers' updates through Redis shards.

        Per the paper's scalability analysis the strain scales with
        P * bytes / shards; each worker performs one push and P-1 pulls, each
        paying one RTT, pipelined 4-wide (the MLLess client batches pulls).
        """
        p = max(n_workers, 1)
        wire = bytes_out * p / (self.redis_bw_Bps * max(n_redis, 1))
        rtts = (1 + (p - 1) / 4.0) * self.redis_rtt_s
        return wire + rtts

    def allreduce_time(self, dense_bytes: float, n_workers: int) -> float:
        """Serverful ring all-reduce (the PyTorch/Gloo baseline)."""
        p = max(n_workers, 1)
        if p == 1:
            return 0.0
        wire = 2.0 * (p - 1) / p * dense_bytes / self.ring_bw_Bps
        return wire + 2.0 * (p - 1) * self.ring_latency_s
