"""Insignificance-bounded Synchronous Parallel (ISP) — the MLLess significance filter.

This is the paper's first contribution (§4.1): a synchronous consistency model
in which each worker accumulates its per-parameter updates and broadcasts the
accumulated update only once it becomes *significant* relative to the current
parameter value:

    | sum_{t'=t_p..t} u_{i,t'} / x_{i,t} | > v_t ,     v_t = v / sqrt(t).

Insignificant updates stay in a local *residual*. Theorem 1 of the paper shows
O(sqrt(T)) regret for convex SGD under this filter, so convergence is
preserved while communication shrinks by the filtered fraction.

Two execution semantics share this module (see DESIGN.md §2):

* **Replica semantics** (paper-faithful): every worker keeps a divergent local
  model copy; only broadcasts are filtered. Used by ``core.simulator``.
* **Error-feedback semantics** (SPMD adaptation): parameters are shared across
  data-parallel shards; each shard keeps a residual and contributes only the
  significant part of ``residual + update`` to the collective. Used by the pod
  training loop (``dist.compression``).

Everything here is pytree-generic and jit-safe (pure ``jax.numpy``); the
Pallas-fused hot path lives in ``repro.kernels.significance`` and is verified
against this module's semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any

_EPS = 1e-12  # guards |x| = 0 denominators (paper implicitly assumes x != 0)


@dataclasses.dataclass(frozen=True)
class ISPConfig:
    """Static configuration of the significance filter.

    Attributes:
      v: initial significance threshold (paper uses v = 0.7 in §6.3). v = 0
        reduces ISP to BSP exactly (Corollary 1).
      decay: if True the threshold decays as ``v_t = v / sqrt(t)`` (Theorem 1
        schedule); if False a constant threshold is used (the micro-benchmark
        sweeps of Fig. 5 vary a fixed v).
      absolute_floor: optional absolute-magnitude floor: entries whose
        parameter value is ~0 are compared against this floor instead of a
        relative one, preventing the filter from locking parameters at zero.
    """

    v: float = 0.7
    decay: bool = True
    absolute_floor: float = 1e-8

    def threshold(self, step: jax.Array | int) -> jax.Array:
        """v_t at 1-indexed step ``step``."""
        t = jnp.maximum(jnp.asarray(step, jnp.float32), 1.0)
        if self.decay:
            return jnp.asarray(self.v, jnp.float32) / jnp.sqrt(t)
        return jnp.asarray(self.v, jnp.float32)


class ISPState(NamedTuple):
    """Carried filter state: per-parameter residual plus the step counter."""

    residual: PyTree  # same structure/dtypes as the parameters
    step: jax.Array  # int32 scalar, 1-indexed (t in the paper)


def init_state(params: PyTree) -> ISPState:
    """Zero residual with the structure of ``params``."""
    residual = jax.tree.map(jnp.zeros_like, params)
    return ISPState(residual=residual, step=jnp.asarray(1, jnp.int32))


def significance_split(
    acc: jax.Array,
    x: jax.Array,
    v_t: jax.Array,
    absolute_floor: float = 1e-8,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Split an accumulated update into (significant, residual, mask).

    Implements the paper's per-parameter test ``|acc / x| > v_t`` with an
    absolute floor for |x| ~ 0. Returns ``(sig, res, mask)`` with
    ``sig + res == acc`` exactly and ``mask`` the boolean significance mask.
    """
    denom = jnp.maximum(jnp.abs(x), absolute_floor)
    mask = jnp.abs(acc) > v_t * denom
    sig = jnp.where(mask, acc, jnp.zeros_like(acc))
    res = jnp.where(mask, jnp.zeros_like(acc), acc)
    return sig, res, mask


def filter_update(
    config: ISPConfig,
    state: ISPState,
    update: PyTree,
    params: PyTree,
) -> tuple[PyTree, ISPState, PyTree]:
    """One ISP filtering step over a full pytree of updates.

    Args:
      config: filter configuration.
      state: carried ``ISPState``.
      update: this step's local update ``u_t`` (e.g. ``-lr * grad``).
      params: current (noisy) parameter values ``x_t`` used as the
        significance denominator.

    Returns:
      ``(significant, new_state, masks)`` where ``significant`` is the pytree
      to be communicated (zeros where filtered), ``new_state`` carries the
      accumulated residual, and ``masks`` the per-leaf boolean masks (used for
      communication accounting and tests).
    """
    v_t = config.threshold(state.step)

    def leaf(u, x, r):
        acc = r + u
        return significance_split(acc, x, v_t, config.absolute_floor)

    out = jax.tree.map(leaf, update, params, state.residual)
    # unzip the 3-tuples leaf-wise
    treedef = jax.tree.structure(params)
    leaves = treedef.flatten_up_to(out)
    sig = treedef.unflatten([l[0] for l in leaves])
    res = treedef.unflatten([l[1] for l in leaves])
    masks = treedef.unflatten([l[2] for l in leaves])
    new_state = ISPState(residual=res, step=state.step + 1)
    return sig, new_state, masks


def communicated_fraction(masks: PyTree) -> jax.Array:
    """Fraction of parameters whose update was communicated this step."""
    sizes = jax.tree.map(lambda m: jnp.asarray(m.size, jnp.float32), masks)
    hits = jax.tree.map(lambda m: jnp.sum(m.astype(jnp.float32)), masks)
    total = jax.tree.reduce(jnp.add, sizes)
    hit = jax.tree.reduce(jnp.add, hits)
    return hit / jnp.maximum(total, 1.0)


def communicated_bytes(masks: PyTree, bytes_per_entry: int = 8) -> jax.Array:
    """Bytes a sparse (value+index) encoding of the significant entries costs.

    The paper's workers push sparse-encoded updates through Redis; we charge
    ``bytes_per_entry`` (default fp32 value + int32 index) per significant
    entry. Used by the simulator's communication cost model.
    """
    hits = jax.tree.map(lambda m: jnp.sum(m.astype(jnp.float32)), masks)
    hit = jax.tree.reduce(jnp.add, hits)
    return hit * bytes_per_entry


def dense_bytes(params: PyTree, bytes_per_entry: int = 4) -> float:
    """Bytes of a dense encoding of a full update (the BSP cost)."""
    sizes = jax.tree.map(lambda p: p.size, params)
    return float(jax.tree.reduce(lambda a, b: a + b, sizes)) * bytes_per_entry


def flush(state: ISPState) -> tuple[PyTree, ISPState]:
    """Emit the whole residual (used on eviction / final sync) and clear it.

    The paper's eviction policy (§4.2) has a leaving worker publish its full
    local replica; in error-feedback semantics the equivalent is flushing the
    residual into the shared parameters.
    """
    zeros = jax.tree.map(jnp.zeros_like, state.residual)
    return state.residual, ISPState(residual=zeros, step=state.step)


def residual_relative_norm(state: ISPState, params: PyTree) -> jax.Array:
    """max_i |r_i| / max(|x_i|, floor) — the consistency-bound diagnostic.

    Theorem 1's noisy-view deviation is bounded by the per-parameter
    significance test; this returns the tightest bound currently witnessed,
    which tests assert is <= v_t.
    """

    def leaf(r, x):
        return jnp.max(jnp.abs(r) / jnp.maximum(jnp.abs(x), _EPS))

    vals = jax.tree.map(leaf, state.residual, params)
    return jax.tree.reduce(jnp.maximum, vals)
