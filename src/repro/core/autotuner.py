"""Scale-in auto-tuner (paper §4.2) — host-side worker-pool controller.

From an initial pool of P workers, the scheduler:

1. waits for the loss curve's *knee* (threshold on the first derivative);
2. at the knee, fits the reference curve L_P(t) (Eq. 2) on the fast-
   convergence losses and estimates the reference step duration d_P;
3. immediately evicts one worker, then, on every scheduling interval:
   - *estimation phase*: fits a slow-convergence curve l_p(t) (Eq. 3) on the
     losses observed since the last removal, and re-estimates step duration
     d_p (steps get faster with fewer workers — communication is O~(p));
   - *decision phase*: computes the projected relative loss degradation over
     horizon Delta,

         s_Delta(t) = [L_P(t + floor(Delta/d_P)) - l_p(t + floor(Delta/d_p))]
                      / L_P(t + floor(Delta/d_P)),

     and removes another worker iff s_Delta(t) < S.

The controller is substrate-agnostic: the serverless simulator feeds it
(loss, step-duration) observations and obeys its eviction decisions; the pod
runtime maps decisions onto elastic DP-axis re-meshing (dist/elastic.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import curves


@dataclasses.dataclass(frozen=True)
class AutoTunerConfig:
    threshold_S: float = 0.05  # scaling-down condition s_Delta(t) < S
    sched_interval_s: float = 20.0  # paper §6.2.2
    delta_s: float = 10.0  # horizon Delta (= half the scheduling epoch)
    knee_slope_threshold: float = 0.05
    knee_window: int = 5
    ewma_alpha: float = 0.3
    min_workers: int = 1
    min_points_for_fit: int = 8


@dataclasses.dataclass
class Decision:
    remove_worker: bool
    s_delta: Optional[float]  # None while pre-knee or under-observed
    reason: str


class ScaleInAutoTuner:
    """Stateful controller; one instance per training job."""

    def __init__(self, config: AutoTunerConfig, initial_workers: int):
        self.config = config
        self.P = initial_workers
        self.pool = initial_workers
        # observation streams
        self._steps: list[int] = []
        self._losses: list[float] = []
        self._durations: list[float] = []
        # region bookkeeping
        self.knee_step: Optional[int] = None
        self.reference: Optional[curves.FittedCurve] = None
        self.d_P: Optional[float] = None
        self._last_removal_idx = 0  # index into streams of the last eviction
        self._last_sched_time = 0.0
        self._time = 0.0

    # -- observation ----------------------------------------------------------

    def observe(self, step: int, loss: float, step_duration_s: float) -> None:
        self._steps.append(int(step))
        self._losses.append(float(loss))
        self._durations.append(float(step_duration_s))
        self._time += float(step_duration_s)

    @property
    def smoothed_losses(self) -> np.ndarray:
        return curves.ewma(self._losses, self.config.ewma_alpha)

    # -- phases ---------------------------------------------------------------

    def _maybe_find_knee(self) -> None:
        if self.knee_step is not None:
            return
        idx = curves.detect_knee(
            self.smoothed_losses,
            self.config.knee_slope_threshold,
            self.config.knee_window,
        )
        if idx is None:
            return
        self.knee_step = self._steps[min(idx, len(self._steps) - 1)]
        t = np.asarray(self._steps, dtype=np.float64)
        y = self.smoothed_losses
        self.reference = curves.fit_reference(t, y)
        # Exclude the first observation from the reference step duration: it
        # carries the XLA-compile warm-up (the same policy fig6 applies to
        # measured_step_s_mean), which would inflate d_P and shrink the
        # floor(Delta/d_P) horizon every later decision is scored against.
        steady = self._durations[1:] or self._durations
        self.d_P = float(np.mean(steady))

    def _estimate_current(self) -> tuple[Optional[curves.FittedCurve], float]:
        """Fit l_p(t) on observations since the last removal; estimate d_p."""
        lo = self._last_removal_idx
        if len(self._steps) - lo < self.config.min_points_for_fit:
            return None, float(np.mean(self._durations[lo:] or self._durations))
        t = np.asarray(self._steps[lo:], dtype=np.float64)
        y = curves.ewma(self._losses[lo:], self.config.ewma_alpha)
        return curves.fit_slow(t, y), float(np.mean(self._durations[lo:]))

    # -- decision -------------------------------------------------------------

    def decide(self) -> Decision:
        """Called by the runtime whenever a scheduling interval elapses."""
        cfg = self.config
        self._maybe_find_knee()
        if self.knee_step is None:
            return Decision(False, None, "pre-knee")
        if self.pool <= cfg.min_workers:
            return Decision(False, None, "at-min-pool")
        if self._time - self._last_sched_time < cfg.sched_interval_s:
            return Decision(False, None, "interval-not-elapsed")

        # First eviction right at the knee (paper: "removes the worker with
        # the lowest-quality replica ... and waits for the next interval").
        if self._last_removal_idx == 0 and self.pool == self.P:
            self._record_removal()
            return Decision(True, None, "knee-initial-eviction")

        ell, d_p = self._estimate_current()
        if ell is None or self.reference is None or self.d_P is None:
            # Consume the interval like every other post-knee outcome:
            # without this an under-observed tuner re-fires the fit on every
            # call until min_points accumulate, ignoring sched_interval_s.
            self._last_sched_time = self._time
            return Decision(False, None, "under-observed")

        t_now = float(self._steps[-1])
        horiz_P = t_now + np.floor(cfg.delta_s / max(self.d_P, 1e-9))
        horiz_p = t_now + np.floor(cfg.delta_s / max(d_p, 1e-9))
        L = float(self.reference(horiz_P))
        l = float(ell(horiz_p))
        s_delta = (L - l) / L if abs(L) > 1e-12 else 0.0

        if s_delta < cfg.threshold_S:
            self._record_removal()
            return Decision(True, s_delta, "scale-in")
        self._last_sched_time = self._time
        return Decision(False, s_delta, "above-threshold")

    def _record_removal(self) -> None:
        self.pool -= 1
        self._last_removal_idx = len(self._steps)
        self._last_sched_time = self._time

    # -- introspection --------------------------------------------------------

    def summary(self) -> dict:
        return {
            "initial_workers": self.P,
            "final_workers": self.pool,
            "knee_step": self.knee_step,
            "reference_theta": None
            if self.reference is None
            else self.reference.theta.tolist(),
            "d_P": self.d_P,
        }


def evict_and_reintegrate(replicas, evicted: int, active_mask):
    """Paper's eviction policy: the leaving worker publishes its replica and
    every active worker averages it into its own local model:

        x_{p'} <- (x_evicted + x_{p'}) / 2

    ``replicas`` leaves have leading worker axis (P, ...); ``active_mask`` is
    a bool (P,) with the evicted worker already cleared. Returns new replicas
    (evicted slot left in place but inert).
    """
    import jax.numpy as jnp

    def leaf(x):
        leaving = x[evicted]
        mask = active_mask.reshape((-1,) + (1,) * (x.ndim - 1))
        averaged = 0.5 * (x + leaving[None])
        return jnp.where(mask, averaged, x)

    import jax

    return jax.tree.map(leaf, replicas)
