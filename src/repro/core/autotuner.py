"""Scale-in auto-tuner (paper §4.2) — host-side worker-pool controller.

From an initial pool of P workers, the scheduler:

1. waits for the loss curve's *knee* (threshold on the first derivative);
2. at the knee, fits the reference curve L_P(t) (Eq. 2) on the fast-
   convergence losses and estimates the reference step duration d_P;
3. immediately evicts one worker, then, on every scheduling interval:
   - *estimation phase*: fits a slow-convergence curve l_p(t) (Eq. 3) on the
     losses observed since the last removal, and re-estimates step duration
     d_p (steps get faster with fewer workers — communication is O~(p));
   - *decision phase*: computes the projected relative loss degradation over
     horizon Delta,

         s_Delta(t) = [L_P(t + floor(Delta/d_P)) - l_p(t + floor(Delta/d_p))]
                      / L_P(t + floor(Delta/d_P)),

     and removes another worker iff s_Delta(t) < S.

The controller is substrate-agnostic: the serverless simulator feeds it
(loss, step-duration) observations and obeys its eviction decisions; the pod
runtime maps decisions onto elastic DP-axis re-meshing (dist/elastic.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import curves


@dataclasses.dataclass(frozen=True)
class AutoTunerConfig:
    threshold_S: float = 0.05  # scaling-down condition s_Delta(t) < S
    sched_interval_s: float = 20.0  # paper §6.2.2
    delta_s: float = 10.0  # horizon Delta (= half the scheduling epoch)
    knee_slope_threshold: float = 0.05
    knee_window: int = 5
    ewma_alpha: float = 0.3
    min_workers: int = 1
    min_points_for_fit: int = 8


@dataclasses.dataclass
class Decision:
    remove_worker: bool
    s_delta: Optional[float]  # None while pre-knee or under-observed
    reason: str


class ScaleInAutoTuner:
    """Stateful controller; one instance per training job."""

    def __init__(self, config: AutoTunerConfig, initial_workers: int):
        self.config = config
        self.P = initial_workers
        self.pool = initial_workers
        # observation streams
        self._steps: list[int] = []
        self._losses: list[float] = []
        self._durations: list[float] = []
        # region bookkeeping
        self.knee_step: Optional[int] = None
        self.reference: Optional[curves.FittedCurve] = None
        self.d_P: Optional[float] = None
        self._last_removal_idx = 0  # index into streams of the last eviction
        self._last_sched_time = 0.0
        self._time = 0.0

    # -- observation ----------------------------------------------------------

    def observe(self, step: int, loss: float, step_duration_s: float) -> None:
        self._steps.append(int(step))
        self._losses.append(float(loss))
        self._durations.append(float(step_duration_s))
        self._time += float(step_duration_s)

    @property
    def smoothed_losses(self) -> np.ndarray:
        return curves.ewma(self._losses, self.config.ewma_alpha)

    # -- phases ---------------------------------------------------------------

    def _maybe_find_knee(self) -> None:
        if self.knee_step is not None:
            return
        idx = curves.detect_knee(
            self.smoothed_losses,
            self.config.knee_slope_threshold,
            self.config.knee_window,
        )
        if idx is None:
            return
        self.knee_step = self._steps[min(idx, len(self._steps) - 1)]
        t = np.asarray(self._steps, dtype=np.float64)
        y = self.smoothed_losses
        self.reference = curves.fit_reference(t, y)
        # Exclude the first observation from the reference step duration: it
        # carries the XLA-compile warm-up (the same policy fig6 applies to
        # measured_step_s_mean), which would inflate d_P and shrink the
        # floor(Delta/d_P) horizon every later decision is scored against.
        steady = self._durations[1:] or self._durations
        self.d_P = float(np.mean(steady))

    def _estimate_current(self) -> tuple[Optional[curves.FittedCurve], float]:
        """Fit l_p(t) on observations since the last removal; estimate d_p."""
        lo = self._last_removal_idx
        if len(self._steps) - lo < self.config.min_points_for_fit:
            return None, float(np.mean(self._durations[lo:] or self._durations))
        t = np.asarray(self._steps[lo:], dtype=np.float64)
        y = curves.ewma(self._losses[lo:], self.config.ewma_alpha)
        return curves.fit_slow(t, y), float(np.mean(self._durations[lo:]))

    # -- decision -------------------------------------------------------------

    def decide(self) -> Decision:
        """Called by the runtime whenever a scheduling interval elapses."""
        cfg = self.config
        self._maybe_find_knee()
        # Interval accounting is uniform across ALL outcomes: an elapsed
        # interval is consumed here, whatever decide() goes on to return.
        # Previously pre-knee/at-min-pool returns left _last_sched_time
        # stale, so the first post-knee decision fired immediately off a
        # timestamp from before the knee was even found.
        interval_elapsed = (
            self._time - self._last_sched_time >= cfg.sched_interval_s
        )
        if interval_elapsed:
            self._last_sched_time = self._time
        if self.knee_step is None:
            return Decision(False, None, "pre-knee")
        if self.pool <= cfg.min_workers:
            return Decision(False, None, "at-min-pool")
        if not interval_elapsed:
            return Decision(False, None, "interval-not-elapsed")

        # First eviction right at the knee (paper: "removes the worker with
        # the lowest-quality replica ... and waits for the next interval").
        if self._last_removal_idx == 0 and self.pool == self.P:
            self._record_removal()
            return Decision(True, None, "knee-initial-eviction")

        ell, d_p = self._estimate_current()
        if ell is None or self.reference is None or self.d_P is None:
            return Decision(False, None, "under-observed")

        t_now = float(self._steps[-1])
        horiz_P = t_now + np.floor(cfg.delta_s / max(self.d_P, 1e-9))
        horiz_p = t_now + np.floor(cfg.delta_s / max(d_p, 1e-9))
        L = float(self.reference(horiz_P))
        l = float(ell(horiz_p))
        s_delta = (L - l) / L if abs(L) > 1e-12 else 0.0

        if s_delta < cfg.threshold_S:
            self._record_removal()
            return Decision(True, s_delta, "scale-in")
        return Decision(False, s_delta, "above-threshold")

    def _record_removal(self) -> None:
        self.pool -= 1
        self._last_removal_idx = len(self._steps)
        self._last_sched_time = self._time

    # -- introspection --------------------------------------------------------

    def summary(self) -> dict:
        return {
            "initial_workers": self.P,
            "final_workers": self.pool,
            "knee_step": self.knee_step,
            "reference_theta": None
            if self.reference is None
            else self.reference.theta.tolist(),
            "d_P": self.d_P,
        }


@dataclasses.dataclass(frozen=True)
class TopologyTunerConfig:
    explore_steps: int = 6  # measured (post-warmup) steps per cell
    warmup_steps: int = 1  # dropped per cell: XLA re-warm after a re-shard
    rel_tolerance: float = 0.05  # p50s within this are a tie


class TopologyTuner:
    """Explore-then-commit co-tuner over topology cells (DESIGN.md §16).

    A *cell* is a full knob assignment ``{n_brokers, transport,
    wire_scheme, shard_split_bytes}``; cell 0 is the topology the job
    started with.  The tuner spends ``warmup_steps + explore_steps``
    measured steps in each cell (the warm-up is dropped — a re-shard
    re-triggers XLA compilation on the respawned workers), then commits
    to the cell with the lowest step-duration p50.  Cells whose p50s are
    within ``rel_tolerance`` of the best are tied; ties break on the
    simulator's cost model (``CommModel.indirect_exchange_time`` with the
    cell's broker count — the same exchange-time term the simulator
    prices, so tuner preference and simulated cost agree by
    construction), then on p50, then on cell order.

    The tuner only *recommends* — ``next_action()`` returns
    ``("explore", cell)`` / ``("commit", cell)`` / ``None`` and the
    supervisor performs the WAL-coordinated handover.  ``abandon()``
    stops the experiment (e.g. the job is too close to its end for
    another fence).
    """

    def __init__(
        self,
        cells: list,
        config: Optional[TopologyTunerConfig] = None,
        comm=None,
        bytes_per_step: float = 0.0,
        n_workers: int = 1,
    ):
        if not cells:
            raise ValueError("TopologyTuner needs at least one cell")
        self.cells = [dict(c) for c in cells]
        self.config = config or TopologyTunerConfig()
        self.comm = comm
        self.bytes_per_step = float(bytes_per_step)
        self.n_workers = int(n_workers)
        self.active = 0
        self.committed: Optional[int] = None
        self._abandoned = False
        self._durs: list[list[float]] = [[] for _ in self.cells]
        self._phases: list[dict[str, list[float]]] = [
            {} for _ in self.cells
        ]

    def observe(self, dur_s: float, phases: Optional[dict] = None) -> None:
        """Feed one measured step of the ACTIVE cell: wall duration plus
        the per-phase seconds dict the workers already report."""
        self._durs[self.active].append(float(dur_s))
        for k, v in (phases or {}).items():
            self._phases[self.active].setdefault(k, []).append(float(v))

    def _steady(self, i: int) -> list[float]:
        return self._durs[i][self.config.warmup_steps:]

    def cell_stats(self, i: int) -> dict:
        durs = self._steady(i)
        stats: dict = {
            "cell": dict(self.cells[i]),
            "n_steps": len(durs),
            "p50": float(np.percentile(durs, 50)) if durs else None,
            "p95": float(np.percentile(durs, 95)) if durs else None,
        }
        w = self.config.warmup_steps
        stats["phase_p50"] = {
            k: float(np.percentile(v[w:], 50))
            for k, v in self._phases[i].items()
            if v[w:]
        }
        stats["phase_p95"] = {
            k: float(np.percentile(v[w:], 95))
            for k, v in self._phases[i].items()
            if v[w:]
        }
        return stats

    def _model_cost(self, cell: dict) -> float:
        if self.comm is None:
            return 0.0
        return float(
            self.comm.indirect_exchange_time(
                self.bytes_per_step,
                self.n_workers,
                n_redis=int(cell.get("n_brokers", 1)),
            )
        )

    def _pick_best(self) -> int:
        p50s = [
            float(np.percentile(self._steady(i), 50))
            if self._steady(i)
            else float("inf")
            for i in range(len(self.cells))
        ]
        best = min(p50s)
        tied = [
            i
            for i, p in enumerate(p50s)
            if p <= best * (1.0 + self.config.rel_tolerance)
        ]
        return min(
            tied, key=lambda i: (self._model_cost(self.cells[i]), p50s[i], i)
        )

    def next_action(self) -> Optional[tuple[str, dict]]:
        """``None`` (keep measuring), ``("explore", cell)`` (re-shard to
        the next cell), or ``("commit", cell)`` (final answer — re-shard
        there iff it differs from the current topology).

        An explore action does NOT advance the active cell: steps
        published between the fence mint and the handover completion
        still ran the old topology and must land in the old cell's
        accounting — the runtime calls ``cell_started()`` once the
        handover actually completed."""
        if self.committed is not None or self._abandoned:
            return None
        need = self.config.warmup_steps + self.config.explore_steps
        if len(self._durs[self.active]) < need:
            return None
        if self.active + 1 < len(self.cells):
            return ("explore", dict(self.cells[self.active + 1]))
        best = self._pick_best()
        self.committed = best
        self.active = best
        return ("commit", dict(self.cells[best]))

    def cell_started(self) -> None:
        """The handover to the next explore cell completed: observations
        from here on belong to it.  A no-op after commit (post-commit
        steps run the committed cell, which is already active)."""
        if self.committed is None and self.active + 1 < len(self.cells):
            self.active += 1

    def abandon(self) -> None:
        self._abandoned = True

    def summary(self) -> dict:
        return {
            "cells": [self.cell_stats(i) for i in range(len(self.cells))],
            "chosen": None if self.committed is None else self.committed,
            "chosen_cell": None
            if self.committed is None
            else dict(self.cells[self.committed]),
            "committed": self.committed is not None,
            "abandoned": self._abandoned,
        }


def evict_and_reintegrate(replicas, evicted: int, active_mask):
    """Paper's eviction policy: the leaving worker publishes its replica and
    every active worker averages it into its own local model:

        x_{p'} <- (x_evicted + x_{p'}) / 2

    ``replicas`` leaves have leading worker axis (P, ...); ``active_mask`` is
    a bool (P,) with the evicted worker already cleared. Returns new replicas
    (evicted slot left in place but inert).
    """
    import jax.numpy as jnp

    def leaf(x):
        leaving = x[evicted]
        mask = active_mask.reshape((-1,) + (1,) * (x.ndim - 1))
        averaged = 0.5 * (x + leaving[None])
        return jnp.where(mask, averaged, x)

    import jax

    return jax.tree.map(leaf, replicas)
