"""MLLess core: ISP significance filter, consistency models, scale-in
auto-tuner, billing/cost models, and the serverless execution simulator."""

from repro.core.isp import (  # noqa: F401
    ISPConfig,
    ISPState,
    init_state,
    filter_update,
    significance_split,
    communicated_fraction,
)
from repro.core.consistency import ConsistencyConfig, Model  # noqa: F401
from repro.core.autotuner import AutoTunerConfig, ScaleInAutoTuner  # noqa: F401
from repro.core.billing import CommModel, faas_cost, iaas_cost, perf_per_dollar  # noqa: F401
from repro.core.simulator import (  # noqa: F401
    Platform,
    ServerlessSimulator,
    SimulatorConfig,
)
