"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar).

mLSTM is a linear-attention-style cell with per-step gates:

    C_t = f_t * C_{t-1} + i_t * (v_t k_t^T)     # (Dh, Dh) matrix memory
    n_t = f_t * n_{t-1} + i_t * k_t             # normalizer
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

Training uses the **chunkwise-parallel** form (intra-chunk quadratic with
decay mask, inter-chunk recurrent carry) — O(S * c) memory, matmul-dominated
(MXU-friendly), the TPU-native counterpart of the paper's fused CUDA kernel.
Gate simplification, documented in DESIGN.md §8: sigmoid input gates instead
of stabilized exponential gating (identical FLOP/memory profile; the
stabilizer state is an artifact of exp-gating only).

sLSTM has recurrent (h_{t-1} -> gates) connections, so it is inherently
sequential: one fp32 ``lax.scan`` over time. This is why the 7:1 mLSTM:sLSTM
pattern exists — the roofline table shows the sLSTM layers' serialization
cost directly.

Both blocks carry xLSTM's internal up/down projections (d_ff = 0 in the
assigned config: there is no separate FF block).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from repro.models.config import ArchConfig
from repro.models.params import ParamDef

PyTree = Any

_CHUNK = 256


def _axes_set(ax) -> set:
    if ax is None:
        return set()
    if isinstance(ax, str):
        return {ax}
    return set(a for a in ax if a)


def _inner(cfg: ArchConfig) -> int:
    return int(cfg.d_model * cfg.lstm_proj_factor)


# ---------------------------------------------------------------- mLSTM ------


def mlstm_defs(cfg: ArchConfig) -> PyTree:
    d = cfg.d_model
    di = _inner(cfg)
    h = cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_up": ParamDef((d, di), dt, ("data", "model")),
        "w_gate": ParamDef((d, di), dt, ("data", "model")),
        "wq": ParamDef((di, di), dt, ("data", "model")),
        "wk": ParamDef((di, di), dt, ("data", "model")),
        "wv": ParamDef((di, di), dt, ("data", "model")),
        "w_if": ParamDef((di, 2 * h), jnp.float32, ("data", None)),
        "b_if": ParamDef((2 * h,), jnp.float32, (None,), "zeros"),
        "w_down": ParamDef((di, d), dt, ("model", "data")),
    }


def mlstm_cache_defs(cfg: ArchConfig, batch: int, policy) -> PyTree:
    h = cfg.n_heads
    dh = _inner(cfg) // h
    bax = policy.batch if batch > 1 else None
    # shard the (dh, dh) matrix memory on its first dh dim — head counts
    # (4) don't divide the model axis, but dh (512) always does
    return {
        "C": ParamDef((batch, h, dh, dh), jnp.float32,
                      (bax, None, "model", None), "zeros"),
        "n": ParamDef((batch, h, dh), jnp.float32, (bax, None, "model"),
                      "zeros"),
    }


def _mlstm_chunk(q, k, v, log_f, i_gate, C0, n0):
    """One chunk of the chunkwise-parallel mLSTM.

    q/k/v: (B, H, c, Dh); log_f, i_gate: (B, H, c); C0: (B, H, Dh, Dh);
    n0: (B, H, Dh). Returns (h, C1, n1).
    """
    b, hh, c, dh = q.shape
    L = jnp.cumsum(log_f, axis=-1)  # (B,H,c) cumulative log decay
    # intra-chunk: D[t,s] = exp(L_t - L_s) * i_s  for s <= t
    diff = L[..., :, None] - L[..., None, :]  # (B,H,c,c)
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri, jnp.exp(diff), 0.0) * i_gate[..., None, :]
    scale = 1.0 / jnp.sqrt(dh)
    att = (q @ k.swapaxes(-1, -2)) * scale * D  # (B,H,c,c)
    intra = att @ v  # (B,H,c,Dh)
    # inter-chunk: h_t += exp(L_t) * (q_t C0), with C0 in k (x) v layout
    decay_t = jnp.exp(L)[..., None]  # (B,H,c,1)
    inter = (q @ C0) * scale * decay_t
    num = intra + inter
    # normalizer: q_t . n_t, with n_t = sum_{s<=t} e^{L_t-L_s} i_s k_s
    #             + e^{L_t} n0  ->  row-sum of att + decayed q.n0
    intra_den = jnp.sum(att, axis=-1, keepdims=True)  # (B,H,c,1)
    inter_den = (q @ n0[..., None]) * scale * decay_t  # (B,H,c,1)
    den = jnp.abs(intra_den + inter_den)
    h = num / jnp.maximum(den, 1.0)
    # state update: C1 = exp(L_c) C0 + sum_s exp(L_c - L_s) i_s k_s v_s^T
    w = jnp.exp(L[..., -1:] - L) * i_gate  # (B,H,c)
    C1 = jnp.exp(L[..., -1])[..., None, None] * C0 + jnp.einsum(
        "bhc,bhcd,bhce->bhde", w, k, v
    )
    n1 = jnp.exp(L[..., -1])[..., None] * n0 + jnp.einsum("bhc,bhcd->bhd", w, k)
    return h, C1, n1


def mlstm_apply(
    cfg: ArchConfig,
    p: PyTree,
    x: jax.Array,
    cache: Optional[PyTree] = None,
    decode: bool = False,
    policy=None,
) -> tuple[jax.Array, Optional[PyTree]]:
    """x: (B, S, d) -> (out, new_cache)."""
    b, s, d = x.shape
    h = cfg.n_heads
    di = _inner(cfg)
    dh = di // h
    up = x @ p["w_up"].astype(x.dtype)  # (B,S,di)
    gate = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))

    def heads(m):
        return m.reshape(b, -1, h, dh).swapaxes(1, 2).astype(jnp.float32)

    q = heads(up @ p["wq"].astype(x.dtype))
    k = heads(up @ p["wk"].astype(x.dtype))
    v = heads(up @ p["wv"].astype(x.dtype))
    gates = up.astype(jnp.float32) @ p["w_if"] + p["b_if"]  # (B,S,2H)
    gates = gates.reshape(b, s, 2, h).swapaxes(1, 3)  # (B,H,2,S)
    i_gate = jax.nn.sigmoid(gates[:, :, 0])  # (B,H,S)
    log_f = jax.nn.log_sigmoid(gates[:, :, 1])  # (B,H,S)

    if decode:
        assert cache is not None and s == 1
        f1 = jnp.exp(log_f[..., 0])[..., None, None]
        # k (x) v state layout — must match the chunkwise-parallel form
        C1 = f1 * cache["C"] + (i_gate[..., 0])[..., None, None] * (
            k[:, :, 0, :, None] @ v[:, :, 0, None, :]
        )
        n1 = f1[..., 0] * cache["n"] + i_gate[..., 0][..., None] * k[:, :, 0]
        scale = 1.0 / jnp.sqrt(dh)
        num = jnp.einsum("bhd,bhde->bhe", q[:, :, 0], C1) * scale
        den = jnp.abs(jnp.sum(n1 * q[:, :, 0], -1, keepdims=True)) * scale
        hv = (num / jnp.maximum(den, 1.0))[:, :, None, :]  # (B,H,1,Dh)
        new_cache = {"C": C1, "n": n1}
    else:
        c = min(_CHUNK, s)
        assert s % c == 0, (s, c)
        nch = s // c

        def body(carry, xs):
            C0, n0 = carry
            qc, kc, vc, lfc, igc = xs
            hv, C1, n1 = _mlstm_chunk(qc, kc, vc, lfc, igc, C0, n0)
            return (C1, n1), hv

        def split(m):  # (B,H,S,*) -> (nch, B,H,c,*)
            return m.reshape(b, h, nch, c, *m.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)

        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        if cache is not None:
            C0, n0 = cache["C"], cache["n"]
        elif policy is not None:
            # pin the recurrent carry to the batch sharding — fresh zeros
            # carry no sharding, and GSPMD would replicate the whole scan
            C0 = policy.constrain(C0, (policy.batch, None, None, None))
            n0 = policy.constrain(n0, (policy.batch, None, None))
        (C1, n1), hv = jax.lax.scan(
            body, (C0, n0),
            (split(q), split(k), split(v), split(log_f), split(i_gate)),
        )
        hv = hv.swapaxes(1, 2).swapaxes(0, 2).reshape(b, h, s, dh)
        new_cache = {"C": C1, "n": n1} if cache is not None else None

    merged = hv.swapaxes(1, 2).reshape(b, -1, di).astype(x.dtype)
    out = (gate * merged) @ p["w_down"].astype(x.dtype)
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------- sLSTM ------


def slstm_defs(cfg: ArchConfig) -> PyTree:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dt = jnp.dtype(cfg.param_dtype)
    return {
        # input -> 4 gates (i, f, z, o), fused
        "w_in": ParamDef((d, 4 * d), dt, ("data", "model")),
        "b_in": ParamDef((4 * d,), jnp.float32, ("model",), "zeros"),
        # recurrent h_{t-1} -> gates, block-diagonal per head (small; head
        # counts (4) don't divide the model axis -> replicated)
        "r": ParamDef((h, dh, 4 * dh), dt, (None, None, None), init_scale=0.5),
        "w_up": ParamDef((d, _slstm_up(d)), dt, ("data", "model")),
        "w_down": ParamDef((_slstm_up(d), d), dt, ("model", "data")),
    }


def _slstm_up(d: int) -> int:
    """xLSTM's 4/3 FF expansion, rounded to a 256 multiple so the dim is
    shardable over any mesh axis (2048 * 4/3 = 2730 -> 2816)."""
    return ((int(d * 4 / 3) + 255) // 256) * 256


def slstm_cache_defs(cfg: ArchConfig, batch: int, policy) -> PyTree:
    d = cfg.d_model
    bax = policy.batch if batch > 1 else None
    ax = (bax, "model")
    return {
        "c": ParamDef((batch, d), jnp.float32, ax, "zeros"),
        "n": ParamDef((batch, d), jnp.float32, ax, "zeros"),
        "h": ParamDef((batch, d), jnp.float32, ax, "zeros"),
    }


def _slstm_cell(p, xg, state):
    """One timestep. xg: (B, 4d) pre-computed input projection."""
    c, n, h = state
    b, d = c.shape
    hh = p["r"].shape[0]
    dh = d // hh
    # recurrent contribution, block-diagonal per head
    rh = jnp.einsum(
        "bhd,hde->bhe", h.reshape(b, hh, dh), p["r"].astype(jnp.float32)
    )  # (B, H, 4*dh); per-head gates contiguous -> reorder to w_in layout
    rh = rh.reshape(b, hh, 4, dh).swapaxes(1, 2).reshape(b, 4 * d)
    g = xg + rh
    i = jnp.exp(jnp.minimum(g[:, 0 * d : 1 * d], 8.0))  # exp input gate, capped
    f = jax.nn.sigmoid(g[:, 1 * d : 2 * d])
    z = jnp.tanh(g[:, 2 * d : 3 * d])
    o = jax.nn.sigmoid(g[:, 3 * d : 4 * d])
    c1 = f * c + i * z
    n1 = f * n + i
    h1 = o * (c1 / jnp.maximum(jnp.abs(n1), 1.0))
    return (c1, n1, h1), h1


def slstm_apply(
    cfg: ArchConfig,
    p: PyTree,
    x: jax.Array,
    cache: Optional[PyTree] = None,
    decode: bool = False,
    policy=None,
) -> tuple[jax.Array, Optional[PyTree]]:
    b, s, d = x.shape
    xg = (x @ p["w_in"].astype(x.dtype)).astype(jnp.float32) + p["b_in"]

    state = (
        jnp.zeros((b, d), jnp.float32),
        jnp.zeros((b, d), jnp.float32),
        jnp.zeros((b, d), jnp.float32),
    )
    if cache is not None:
        state = (cache["c"], cache["n"], cache["h"])
    elif policy is not None:
        # pin the recurrent carry to the batch sharding (see mlstm_apply)
        state = tuple(
            policy.constrain(t, (policy.batch, None)) for t in state
        )
        xg = policy.constrain(xg, (policy.batch, None, None))

    if decode:
        assert s == 1
        state, h = _slstm_cell(p, xg[:, 0], state)
        hs = h[:, None, :]
        new_cache = {"c": state[0], "n": state[1], "h": state[2]}
    else:
        def run_scan(r_w, xg_, state_):
            def body(carry, xg_t):
                return _slstm_cell({"r": r_w}, xg_t, carry)

            st, hs_ = jax.lax.scan(body, state_, xg_.swapaxes(0, 1))
            return st, hs_.swapaxes(0, 1)  # (B,S,d)

        mesh = getattr(policy, "mesh", None) if policy is not None else None
        bax = getattr(policy, "batch", None) if policy is not None else None
        manual = _axes_set(bax)
        if mesh is not None and manual:
            # shard_map over the batch axes: the time scan is sequential,
            # so GSPMD cannot infer shardings for its (fresh-zeros) carry
            # and cotangents — it replicates the WHOLE 4096-step loop over
            # 'model' (measured 118s memory term for xlstm train before
            # this; EXPERIMENTS.md §Perf). Manual batch sharding makes
            # every step chip-local by construction.
            from jax.sharding import PartitionSpec as P

            state, hs = shard_map(
                run_scan,
                mesh=mesh,
                in_specs=(
                    P(),  # recurrent weights: replicated
                    P(bax, None, None),
                    (P(bax, None),) * 3,
                ),
                out_specs=((P(bax, None),) * 3, P(bax, None, None)),
                axis_names=manual,
                check_vma=False,
            )(p["r"], xg, state)
        else:
            state, hs = run_scan(p["r"], xg, state)
        new_cache = (
            {"c": state[0], "n": state[1], "h": state[2]}
            if cache is not None
            else None
        )

    up = jax.nn.gelu(hs.astype(x.dtype) @ p["w_up"].astype(x.dtype))
    out = up @ p["w_down"].astype(x.dtype)
    return out.astype(x.dtype), new_cache
