"""Probabilistic matrix factorization (Salakhutdinov & Mnih) — paper §6.1.

R (N_u x N_m, partially observed) ~ U @ M, U: (N_u, r), M: (r, N_m).
Minibatches are rating triples (user, movie, rating). Loss is RMSE on the
observed entries (paper's convergence metric) with Gaussian-prior L2 terms.

The gradients are *extremely* sparse — each triple touches one row of U and
one column of M — which is exactly why the paper's significance filter and
MLLess's sparse serialization shine on this workload.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PMFConfig:
    n_users: int
    n_movies: int
    rank: int = 20  # paper: r = 20
    lambda_u: float = 0.02
    lambda_m: float = 0.02


class PMFParams(NamedTuple):
    U: jax.Array  # (n_users, rank)
    M: jax.Array  # (rank, n_movies)


class RatingsBatch(NamedTuple):
    user: jax.Array  # (B,) int32
    movie: jax.Array  # (B,) int32
    rating: jax.Array  # (B,) float32


def init(config: PMFConfig, key: jax.Array) -> PMFParams:
    ku, km = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(config.rank)
    U = scale * jax.random.normal(ku, (config.n_users, config.rank), jnp.float32)
    M = scale * jax.random.normal(km, (config.rank, config.n_movies), jnp.float32)
    return PMFParams(U=U, M=M)


def predict(params: PMFParams, user: jax.Array, movie: jax.Array) -> jax.Array:
    u = params.U[user]  # (B, r)
    m = params.M[:, movie].T  # (B, r)
    return jnp.sum(u * m, axis=-1)


def loss_fn(config: PMFConfig, params: PMFParams, batch: RatingsBatch) -> jax.Array:
    """Regularised MSE over the minibatch (RMSE reported separately)."""
    pred = predict(params, batch.user, batch.movie)
    err = pred - batch.rating
    mse = jnp.mean(jnp.square(err))
    # batch-local prior terms (only touched rows/cols, matching SGD-PMF practice)
    reg = config.lambda_u * jnp.mean(jnp.sum(jnp.square(params.U[batch.user]), -1))
    reg += config.lambda_m * jnp.mean(
        jnp.sum(jnp.square(params.M[:, batch.movie]), 0)
    )
    return mse + reg


def rmse(params: PMFParams, batch: RatingsBatch) -> jax.Array:
    pred = predict(params, batch.user, batch.movie)
    return jnp.sqrt(jnp.mean(jnp.square(pred - batch.rating)))


def grad_fn(config: PMFConfig, params: PMFParams, batch: RatingsBatch):
    return jax.value_and_grad(lambda p: loss_fn(config, p, batch))(params)
