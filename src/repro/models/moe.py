"""Mixture-of-Experts FF with top-k routing and capacity-based dispatch.

Dispatch is the cumsum/scatter formulation (no sort): for each (token, k)
assignment we compute the token's position within its expert's capacity
buffer via a cumulative sum over one-hot expert indicators, drop overflow,
scatter into an (G, E, C, d) buffer, run the expert FFs as one grouped
einsum, and gather back with the softmax gate weights. FLOPs are therefore
O(top_k * capacity_factor * N * d * f) — the *active*-expert cost, not the
all-experts dense cost, which keeps the roofline compute term honest
(DESIGN.md §4: MoE is the paper's sparse-gradient regime analogue).

Sharding: dispatch is GROUP-LOCAL. Tokens are reshaped into G groups, one
per data-parallel shard (policy.moe_groups == product of batch-axis sizes),
so capacity buffers shard over the batch axes and the (group -> expert)
exchange lowers to the all-to-all GSPMD materializes at the expert-parallel
boundary. A single global capacity buffer would be a (E, n*cap/E, d) scatter
target whose sharding GSPMD cannot infer — group-locality is what keeps the
MoE memory footprint per-chip O(local_tokens * d) at mixtral scale.

Expert placement: experts are expert-parallel over 'model' when the expert
count divides the axis (phi3.5: 16e); otherwise each expert is tensor-sliced
over (data, model) (mixtral: 8e < 16) — see moe_defs.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from repro.models.config import ArchConfig
from repro.models.params import ParamDef

PyTree = Any


EP_TARGET = 16  # 'model' axis size of the production meshes


def expert_split(cfg: ArchConfig) -> int:
    """f-dim split factor turning E physical experts into E*split VIRTUAL
    experts so the expert dim always fills the EP axis (mixtral: 8e x 2)."""
    e = cfg.moe.n_experts
    if e % EP_TARGET == 0:
        return 1
    assert EP_TARGET % e == 0, (e, EP_TARGET)
    return EP_TARGET // e


def moe_defs(cfg: ArchConfig) -> PyTree:
    """Expert weights in VIRTUAL-expert layout: (E*split, d, f/split) with
    the virtual-expert dim expert-parallel over 'model'.

    When E < EP_TARGET each physical expert is split into ``split`` f-slices
    that behave as separate experts sharing the routing decision (SwiGLU and
    the down-projection are exactly f-separable: concat of slice outputs ==
    the unsplit output summed over slices). This keeps the (G, E', C, d)
    dispatch buffer shardable over 'model' for every expert count — a
    replicated buffer would force a full all-reduce of the buffer at the
    scatter (measured 101s collective term for mixtral before this fix;
    EXPERIMENTS.md §Perf)."""
    assert cfg.moe is not None
    e, d, f = cfg.moe.n_experts, cfg.d_model, cfg.d_ff
    s = expert_split(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "router": ParamDef((d, e), jnp.float32, (None, None)),
        "w_gate": ParamDef((e * s, d, f // s), dt, ("model", "data", None)),
        "w_up": ParamDef((e * s, d, f // s), dt, ("model", "data", None)),
        "w_down": ParamDef((e * s, f // s, d), dt, ("model", None, "data")),
    }


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts))
    return max(8, ((c + 7) // 8) * 8)  # pad to a sublane multiple


def moe_apply(
    cfg: ArchConfig, p: PyTree, x: jax.Array, policy=None
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    aux_loss is the standard load-balance term (mean gate prob * token
    density per expert, scaled by E) — returned so the train loop can add it.
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.n_experts, m.top_k
    G = getattr(policy, "moe_groups", 1) if policy is not None else 1
    if n % G != 0:
        G = 1
    ng = n // G
    xt = x.reshape(G, ng, d)
    group_ax = getattr(policy, "moe_group_ax", None) if policy else None
    token_ax = getattr(policy, "moe_token_ax", None) if policy else None
    ep_ax = getattr(policy, "moe_ep_ax", None) if policy else None
    if policy is not None:
        xt = policy.constrain(xt, (group_ax, token_ax, None))

    # -- routing (fp32)
    logits = xt.astype(jnp.float32) @ p["router"]  # (G, ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (G, ng, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # -- load-balance aux (Switch-style), over the full global batch
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )  # (E,) fraction of tokens routed to each expert (summed over k)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density / k * mean_prob)

    # -- capacity slots: position of each (token, k) within its PHYSICAL
    #    expert, computed group-locally
    c = capacity(ng, cfg)
    flat_e = expert_ids.reshape(G, ng * k)  # arrival order (token-major)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (G, ng*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot  # exclusive cumsum
    slot = jnp.sum(pos_in_e * onehot, axis=-1)  # (G, ng*k)
    keep = slot < c

    # -- virtual experts: each (token, k) assignment fans out to the
    #    ``split`` f-slices of its expert, same slot in each (moe_defs)
    split = expert_split(cfg)
    e_virt = e * split
    na = ng * k * split  # assignments per group
    tok_idx = jnp.repeat(jnp.arange(ng), k * split)  # (na,)
    flat_ev = (
        flat_e[:, :, None] * split + jnp.arange(split)[None, None, :]
    ).reshape(G, na)
    slot_v = jnp.repeat(slot, split, axis=1)
    keep_v = jnp.repeat(keep, split, axis=1)
    gates_flat = gate_vals.reshape(G, ng * k)

    safe_slot = jnp.where(keep_v, slot_v, c - 1)
    w_assign = jnp.repeat(
        gates_flat * keep.astype(jnp.float32), split, axis=1
    )
    # Weights are STORED 2D-sharded (ZeRO-3); for compute they are either
    # gathered in full (train: groups cover every axis) or re-sharded onto
    # d_ff over 'model' (prefill: groups only cover 'data') — policy.moe_f_ax
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    f_ax = getattr(policy, "moe_f_ax", None) if policy else None
    if policy is not None and f_ax is not None:
        w_gate = policy.constrain(w_gate, (None, None, f_ax))
        w_up = policy.constrain(w_up, (None, None, f_ax))
        w_down = policy.constrain(w_down, (None, f_ax, None))

    a2a = bool(getattr(policy, "moe_a2a", False)) if policy else False

    def dispatch_ff_combine(xt_, ev_, ss_, kv_, wa_, wg_, wu_, wd_):
        """Dispatch -> expert FF -> combine over a (local) block of groups.

        Pure per-group code (vmapped scatter/gather). Under shard_map the
        group dim is manual-sharded, so dispatch is structurally chip-local.

        When ``a2a`` is set (expert parallelism): the local capacity buffers
        are exchanged over 'model' with all_to_all, the expert FF runs on
        RESIDENT weight slices (E'/tp experts per chip — no per-layer
        weight gather), and a second all_to_all returns the outputs. This
        moves tokens (~0.2 GB/chip/layer) instead of expert weights
        (~4.8 GB/layer for mixtral) — EXPERIMENTS.md §Perf iteration 2.
        """
        contrib = jnp.where(kv_[..., None], xt_[:, tok_idx], 0).astype(
            x.dtype
        )

        def scatter_group(ev_g, slot_g, contrib_g):
            return jnp.zeros((e_virt, c, d), x.dtype).at[ev_g, slot_g].add(
                contrib_g, mode="drop"
            )

        buf = jax.vmap(scatter_group)(ev_, ss_, contrib)  # (gl, E', c, d)
        if a2a:
            gl = buf.shape[0]
            # (gl, E', c, d) -> exchange expert shards over 'model':
            # each chip ends with its E'/tp experts x (tp senders * c) slots
            sent = buf.reshape(gl * e_virt, c, d)
            recv = jax.lax.all_to_all(
                sent, "model", split_axis=0, concat_axis=1, tiled=True
            )  # (gl * E'/tp, tp * c, d)
            fbuf = recv.reshape(gl, -1, recv.shape[1], d)  # (gl,E'loc,tp*c,d)
        else:
            fbuf = buf
        gg = jnp.einsum("gecd,edf->gecf", fbuf, wg_.astype(x.dtype))
        uu = jnp.einsum("gecd,edf->gecf", fbuf, wu_.astype(x.dtype))
        h = (jax.nn.silu(gg) * uu).astype(x.dtype)
        out_fbuf = jnp.einsum("gecf,efd->gecd", h, wd_.astype(x.dtype))
        if a2a:
            gl = out_fbuf.shape[0]
            sent_back = out_fbuf.reshape(gl * out_fbuf.shape[1],
                                         out_fbuf.shape[2], d)
            back = jax.lax.all_to_all(
                sent_back, "model", split_axis=1, concat_axis=0, tiled=True
            )  # (gl * E', c, d)
            out_buf = back.reshape(gl, e_virt, c, d)
        else:
            out_buf = out_fbuf
        gathered = jax.vmap(lambda ob, ev, sl: ob[ev, sl])(out_buf, ev_, ss_)
        weighted = gathered * wa_[..., None].astype(x.dtype)
        return jnp.sum(weighted.reshape(-1, ng, k * split, d), axis=2)

    mesh = getattr(policy, "mesh", None) if policy is not None else None
    manual = _axes_set(group_ax)
    if mesh is not None and manual and G > 1:
        # shard_map over the group axes: GSPMD cannot partition the batched
        # capacity scatter/gather (it replicates the buffer and all-reduces
        # token-sized gradients — measured 346s/step of collectives for
        # mixtral); making group-locality STRUCTURAL removes every dispatch
        # collective. Expert weights enter replicated over the group axes
        # (their ZeRO-3 gather is emitted once, outside), and stay auto-
        # sharded on any axis not in `manual` (prefill keeps f over 'model').
        from jax.sharding import PartitionSpec as P

        # a2a mode: expert weights stay RESIDENT, sharded over 'model' on
        # the virtual-expert dim (the ZeRO gather over 'data' still happens
        # outside, but the 16x larger 'model' gather disappears)
        w_spec = P("model", None, None) if a2a else P(None, None, None)
        out = shard_map(
            dispatch_ff_combine,
            mesh=mesh,
            in_specs=(
                P(group_ax, token_ax, None),
                P(group_ax, None),
                P(group_ax, None),
                P(group_ax, None),
                P(group_ax, None),
                w_spec,
                w_spec,
                w_spec,
            ),
            out_specs=P(group_ax, token_ax, None),
            axis_names=manual,
            check_vma=False,
        )(xt, flat_ev, safe_slot, keep_v, w_assign, w_gate, w_up, w_down)
    else:
        out = dispatch_ff_combine(
            xt, flat_ev, safe_slot, keep_v, w_assign, w_gate, w_up, w_down
        )
    return out.reshape(b, s, d), aux.astype(jnp.float32)


def _axes_set(group_ax) -> set:
    if group_ax is None:
        return set()
    if isinstance(group_ax, str):
        return {group_ax}
    return set(group_ax)
