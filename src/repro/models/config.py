"""Architecture configuration — one dataclass drives the whole LM stack.

A model is a stack of *scan groups*; each group is a repeated *superblock*;
a superblock is an ordered tuple of block specs (attention / MoE-FF / RG-LRU /
mLSTM / sLSTM ...). Heterogeneous layer patterns (gemma3's 5 local : 1 global,
recurrentgemma's 2 recurrent : 1 attention, xLSTM's 7 mLSTM : 1 sLSTM) are
expressed as superblocks so the whole depth still lowers as ONE ``lax.scan``
per group — HLO size stays O(pattern), not O(depth), which is what keeps
512-device compiles tractable (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Mixer(enum.Enum):
    """Sequence-mixing block kinds."""

    GLOBAL_ATTN = "global_attn"  # full (causal) attention
    LOCAL_ATTN = "local_attn"  # sliding-window attention
    CROSS_ATTN = "cross_attn"  # encoder-decoder cross attention
    RGLRU = "rglru"  # Griffin-style gated linear recurrence
    MLSTM = "mlstm"  # xLSTM matrix-memory block
    SLSTM = "slstm"  # xLSTM scalar-memory block (sequential)


class FF(enum.Enum):
    """Feed-forward kinds (NONE for xLSTM blocks with internal projections)."""

    SWIGLU = "swiglu"
    GEGLU = "geglu"
    GELU = "gelu"  # plain 2-layer MLP
    MOE = "moe"
    NONE = "none"


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One residual block: pre-norm mixer + pre-norm FF."""

    mixer: Mixer
    ff: FF
    window: Optional[int] = None  # sliding-window size (LOCAL_ATTN)
    rope_base: Optional[float] = 10_000.0  # None = no RoPE (whisper)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Auxiliary encoder (whisper audio / paligemma vision-stub)."""

    n_layers: int
    ctx_len: int  # 1500 audio frames / 256 image patches
    d_model: Optional[int] = None  # defaults to decoder d_model
    precomputed: bool = True  # frontend is a stub: embeddings arrive as input


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # (superblock, repeats) groups; sum(len(sb) * reps) == total layers
    groups: tuple[tuple[tuple[BlockSpec, ...], int], ...]
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None  # enc-dec / VLM prefix tower
    prefix_lm: bool = False  # paligemma: bidirectional prefix attention
    tie_embeddings: bool = True
    max_seq_len: int = 131_072
    sub_quadratic: bool = False  # long_500k eligibility (DESIGN.md §4)
    # dtypes
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # slstm/mlstm internal expansion
    lstm_proj_factor: float = 2.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table's
        vocab dim is shardable over any mesh axis (16/32/...). Padded logit
        columns are masked out of the softmax (layers.chunked_softmax_xent);
        padded rows are dead weights. Standard MaxText-style practice."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def n_layers(self) -> int:
        return sum(len(sb) * reps for sb, reps in self.groups)

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0, (
            f"{self.name}: heads {self.n_heads} % kv {self.n_kv_heads} != 0"
        )
        for sb, reps in self.groups:
            assert reps >= 1 and len(sb) >= 1
            for b in sb:
                if b.ff is FF.MOE:
                    assert self.moe is not None, f"{self.name}: MOE ff without moe cfg"
                if b.mixer is Mixer.LOCAL_ATTN:
                    assert b.window, f"{self.name}: local attn without window"


def uniform_groups(spec: BlockSpec, n_layers: int) -> tuple:
    """Homogeneous stack: one group of n_layers single-block superblocks."""
    return (((spec,), n_layers),)


def pattern_groups(pattern: tuple[BlockSpec, ...], n_layers: int) -> tuple:
    """Repeat ``pattern`` as a superblock; remainder becomes a second group."""
    plen = len(pattern)
    reps, rem = divmod(n_layers, plen)
    groups = []
    if reps:
        groups.append((pattern, reps))
    if rem:
        groups.append((pattern[:rem], 1))
    return tuple(groups)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The DESIGN.md §4 applicability matrix."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "skipped(full-attention)"
    return True, "ok"
