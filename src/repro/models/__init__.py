"""Model zoo: the paper's LR/PMF plus the assigned LM architecture stack."""
