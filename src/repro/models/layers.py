"""Layer primitives: norms, MLPs, embeddings, RoPE — ParamDef-declared.

Convention: every layer exposes ``<layer>_defs(cfg, ...) -> ParamDef tree``
and ``<layer>_apply(cfg, params, x, ...) -> y``. Activations flow in
``cfg.activation_dtype`` (bf16 by default); normalization statistics, softmax
and loss accumulate in fp32 (standard TPU mixed-precision discipline).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, FF
from repro.models.params import ParamDef

PyTree = Any


def adt(cfg: ArchConfig):
    return jnp.dtype(cfg.activation_dtype)


def pdt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---- normalization -----------------------------------------------------------


def norm_defs(cfg: ArchConfig, d: Optional[int] = None) -> PyTree:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": ParamDef((d,), pdt(cfg), (None,), "ones"),
            "bias": ParamDef((d,), pdt(cfg), (None,), "zeros"),
        }
    return {"scale": ParamDef((d,), pdt(cfg), (None,), "ones")}


def norm_apply(cfg: ArchConfig, p: PyTree, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---- feed-forward -------------------------------------------------------------


def ff_defs(cfg: ArchConfig, kind: FF) -> PyTree:
    d, f = cfg.d_model, cfg.d_ff
    dt = pdt(cfg)
    if kind in (FF.SWIGLU, FF.GEGLU):
        return {
            "w_gate": ParamDef((d, f), dt, ("data", "model")),
            "w_up": ParamDef((d, f), dt, ("data", "model")),
            "w_down": ParamDef((f, d), dt, ("model", "data")),
        }
    if kind is FF.GELU:
        return {
            "w_up": ParamDef((d, f), dt, ("data", "model")),
            "b_up": ParamDef((f,), dt, ("model",), "zeros"),
            "w_down": ParamDef((f, d), dt, ("model", "data")),
            "b_down": ParamDef((d,), dt, (None,), "zeros"),
        }
    raise ValueError(f"ff_defs: unsupported {kind}")


def ff_apply(cfg: ArchConfig, kind: FF, p: PyTree, x: jax.Array) -> jax.Array:
    """x: (..., d_model) -> (..., d_model)."""
    if kind in (FF.SWIGLU, FF.GEGLU):
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        act = jax.nn.silu(g) if kind is FF.SWIGLU else jax.nn.gelu(g)
        return ((act * u) @ p["w_down"]).astype(x.dtype)
    if kind is FF.GELU:
        h = jax.nn.gelu(x @ p["w_up"] + p["b_up"].astype(x.dtype))
        return (h @ p["w_down"] + p["b_down"].astype(x.dtype)).astype(x.dtype)
    raise ValueError(f"ff_apply: unsupported {kind}")


# ---- embeddings ----------------------------------------------------------------


def embed_defs(cfg: ArchConfig) -> PyTree:
    defs = {
        "tok": ParamDef(
            (cfg.padded_vocab, cfg.d_model), pdt(cfg), ("model", "data"),
            init_scale=1.0,
        )
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef(
            (cfg.d_model, cfg.padded_vocab), pdt(cfg), ("data", "model")
        )
    return defs


def embed_apply(cfg: ArchConfig, p: PyTree, tokens: jax.Array) -> jax.Array:
    """tokens (B, S) int32 -> (B, S, d_model)."""
    x = jnp.take(p["tok"], tokens, axis=0).astype(adt(cfg))
    # gemma-style sqrt(d) scaling keeps tied-embedding logits sane
    return x * jnp.asarray(jnp.sqrt(cfg.d_model), adt(cfg))


def unembed_apply(cfg: ArchConfig, p: PyTree, x: jax.Array) -> jax.Array:
    """x (..., d_model) -> logits (..., vocab) in fp32."""
    w = p.get("unembed")
    if w is None:
        w = p["tok"].T
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


# ---- rotary position embeddings -------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, base: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) or (S,). Pairs are (even, odd)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal table (fp32, (S, D))."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d_model)
    tab = jnp.zeros((seq_len, d_model), jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(angle))
    tab = tab.at[:, 1::2].set(jnp.cos(angle))
    return tab


# ---- losses ---------------------------------------------------------------------


def chunked_softmax_xent(
    cfg: ArchConfig,
    embed_params: PyTree,
    hidden: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    chunk: int = 512,
) -> jax.Array:
    """Cross entropy without materializing (B, S, vocab).

    Scans over sequence chunks; each chunk computes logits -> logsumexp ->
    label logit, accumulating in fp32. The chunk body is rematerialized in
    the backward pass (jax.checkpoint), so peak memory is O(B*chunk*V_shard)
    rather than O(B*S*V) — this is what makes 256k-vocab training shapes fit
    (DESIGN.md §5).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    rem = s - n_chunks * chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)

    # padded vocab columns must not leak into the softmax normalizer
    vpad = cfg.padded_vocab
    col_valid = (jnp.arange(vpad) < cfg.vocab_size).astype(jnp.float32)
    col_bias = (1.0 - col_valid) * (-1e30)

    # hoist the unembedding out of the chunk scan: under FSDP-2D the table
    # is 2D-sharded and must be gathered to compute logits — gathering once
    # here instead of once per chunk cuts the loss's collective bytes by
    # n_chunks x (gemma3's 262k-vocab table is 2GB: 16 gathers -> 1)
    w_unembed = embed_params.get("unembed")
    if w_unembed is None:
        w_unembed = embed_params["tok"].T
    try:
        w_unembed = jax.lax.with_sharding_constraint(
            w_unembed, jax.sharding.PartitionSpec(None, None)
        )
    except (ValueError, RuntimeError):
        pass  # outside a mesh context (CPU smoke tests)

    @jax.checkpoint
    def chunk_loss(h_c, y_c, m_c):
        logits = (h_c @ w_unembed.astype(h_c.dtype)).astype(jnp.float32)
        logits = logits + col_bias
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, y_c[..., None].astype(jnp.int32), -1)[
            ..., 0
        ]
        return jnp.sum((lse - lab) * m_c), jnp.sum(m_c)

    def body(carry, xs):
        tot, cnt = carry
        h_c, y_c, m_c = xs
        l, n = chunk_loss(h_c, y_c, m_c)
        return (tot + l, cnt + n), None

    hs = hidden[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, d)
    ys = labels[:, : n_chunks * chunk].reshape(b, n_chunks, chunk)
    ms = mask[:, : n_chunks * chunk].reshape(b, n_chunks, chunk)
    (tot, cnt), _ = jax.lax.scan(
        body,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs.swapaxes(0, 1), ys.swapaxes(0, 1), ms.swapaxes(0, 1)),
    )
    if rem:
        l, n = chunk_loss(hidden[:, -rem:], labels[:, -rem:], mask[:, -rem:])
        tot, cnt = tot + l, cnt + n
    return tot / jnp.maximum(cnt, 1.0)
