"""Attention: GQA + RoPE + causal / sliding-window / prefix-LM / cross.

Three execution paths, chosen by sequence length and mode:

* ``dense``   — single einsum + masked softmax. Decode (q_len == 1) and short
  sequences. Memory O(Sq*Skv).
* ``chunked`` — outer ``lax.scan`` over Q chunks (rematerialized), inner scan
  over KV chunks with online-softmax accumulation: the XLA-level flash
  attention. Memory O(chunk^2). Used for train/prefill at long seq.
  NOTE: the inner scan visits all KV chunks and masks — causal upper-triangle
  tiles are wasted flops in this XLA fallback (the Pallas kernel
  ``repro.kernels.flash_attention`` skips them on real TPUs; see
  EXPERIMENTS.md §Perf for the measured gap).
* ``banded``  — sliding-window layers slice one static-width KV band per Q
  chunk (``dynamic_slice``), so SWA flops are O(Sq * window), not O(Sq^2).

All softmax math is fp32 regardless of activation dtype.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig, BlockSpec, Mixer
from repro.models.layers import adt, pdt, rope
from repro.models.params import ParamDef

PyTree = Any

NEG_INF = -1e30
_DEFAULT_CHUNK = 1024
_DENSE_MAX_SEQ = 2048  # dense path threshold


def _divisor_chunk(s: int, chunk: int) -> int:
    """Largest divisor of ``s`` that is <= ``chunk`` (paligemma's
    vision-prefixed sequences are not powers of two)."""
    c = min(chunk, s)
    while s % c:
        c -= 1
    return c


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Activation-sharding annotations (mesh axis names), per shape cell."""

    batch: Any = ("pod", "data")
    heads: Any = "model"
    kv_seq: Any = None  # set to 'data' for long-context decode (cache SP)
    seq: Any = None  # sequence-parallel axis for the residual stream
    moe_groups: int = 1  # group-local MoE dispatch (== # of batch shards)
    moe_group_ax: Any = None  # mesh axes of the MoE group dim
    moe_token_ax: Any = None  # mesh axis of tokens within a group
    moe_ep_ax: Any = None  # expert-parallel axis (decode only: tiny buffers)
    moe_f_ax: Any = None  # d_ff compute sharding of expert weights
    moe_a2a: bool = False  # expert-parallel all-to-all inside shard_map
    mesh: Any = None  # Mesh for shard_map regions (None on CPU smoke paths)

    def constrain(self, x: jax.Array, axes: tuple) -> jax.Array:
        try:
            return jax.lax.with_sharding_constraint(x, P(*axes))
        except (ValueError, RuntimeError):
            return x  # outside a mesh context (CPU smoke tests)


# ---- params -------------------------------------------------------------------


def attn_defs(cfg: ArchConfig, cross: bool = False) -> PyTree:
    """Projection params in FLAT (d, H*Dh) layout.

    Flat layouts keep every sharded dim divisible by the mesh axis for any
    head count (H*Dh is a multiple of 64); heads are split on ACTIVATIONS
    (after the projection), where GSPMD may pad non-divisible head counts
    freely. Explicit jit argument shardings have a hard divisibility rule —
    this layout is what satisfies it for all ten archs.
    """
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = pdt(cfg)
    defs = {
        "wq": ParamDef((d, h * dh), dt, ("data", "model")),
        "wk": ParamDef((d, k * dh), dt, ("data", "model")),
        "wv": ParamDef((d, k * dh), dt, ("data", "model")),
        "wo": ParamDef((h * dh, d), dt, ("model", "data")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h * dh,), dt, ("model",), "zeros")
        defs["bk"] = ParamDef((k * dh,), dt, ("model",), "zeros")
        defs["bv"] = ParamDef((k * dh,), dt, ("model",), "zeros")
    return defs


def cache_defs(
    cfg: ArchConfig,
    spec: BlockSpec,
    batch: int,
    max_len: int,
    policy: ShardingPolicy,
) -> PyTree:
    """KV-cache ParamDefs for one attention block (decode path input)."""
    k, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    if spec.mixer is Mixer.LOCAL_ATTN:
        length = min(max_len, spec.window)  # ring buffer
        seq_ax = None  # ring buffers are short; never sharded on seq
        feat_ax = (policy.heads or "model") if policy.kv_seq is None else None
    else:
        length = max_len
        seq_ax = policy.kv_seq
        # one mesh axis per spec: when seq takes an axis, features stay
        # local; otherwise the flat K*Dh dim takes 'model' (always a
        # multiple of 16 in flat layout) so prefill caches never replicate
        feat_ax = (policy.heads or "model") if seq_ax is None else None
    dt = jnp.dtype(cfg.activation_dtype)
    # flat (B, L, K*Dh) layout: divisible for any kv-head count (see attn_defs)
    ax = (policy.batch if batch > 1 else None, seq_ax, feat_ax)
    return {
        "k": ParamDef((batch, length, k * dh), dt, ax, "zeros"),
        "v": ParamDef((batch, length, k * dh), dt, ax, "zeros"),
    }


# ---- masks ---------------------------------------------------------------------


def _mask(
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Skv,)
    causal: bool,
    window: Optional[int],
    prefix_len: Optional[int],
    k_valid: Optional[jax.Array] = None,  # (Skv,) extra validity (ring bufs)
) -> jax.Array:
    """(Sq, Skv) boolean allow-mask."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        c = kp <= qp
        if prefix_len is not None:
            c = c | (kp < prefix_len)
        m = m & c
    if window is not None:
        m = m & (qp - kp < window)
    if k_valid is not None:
        m = m & k_valid[None, :]
    return m


# ---- cores ---------------------------------------------------------------------


def _dense_core(q, kv_k, kv_v, mask) -> jax.Array:
    """q (B,Sq,K,G,Dh), k/v (B,Skv,K,Dh), mask (Sq,Skv) -> (B,Sq,K,G,Dh)."""
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    logits = jnp.einsum(
        "bqkgd,bckd->bqkgc", q.astype(jnp.float32), kv_k.astype(jnp.float32)
    ) * scale
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", probs, kv_v.astype(jnp.float32))
    return out.astype(q.dtype)


def _online_update(carry, logits, v_chunk):
    """Online-softmax accumulation. carry = (m, l, acc)."""
    m, l, acc = carry
    m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
    correction = jnp.exp(m - m_new)
    p = jnp.exp(logits - m_new[..., None])
    l_new = l * correction + jnp.sum(p, axis=-1)
    acc_new = acc * correction[..., None] + jnp.einsum(
        "bqkgc,bckd->bqkgd", p, v_chunk.astype(jnp.float32)
    )
    return m_new, l_new, acc_new


def _chunked_core(
    q,  # (B, Sq, K, G, Dh)
    kv_k,
    kv_v,  # (B, Skv, K, Dh)
    q_offset: int,
    causal: bool,
    window: Optional[int],
    prefix_len: Optional[int],
    chunk: int = _DEFAULT_CHUNK,
) -> jax.Array:
    """XLA flash: q-chunk outer scan (remat), kv-chunk inner scan."""
    b, sq, kh, g, dh = q.shape
    skv = kv_k.shape[1]
    qc = _divisor_chunk(sq, chunk)
    kc = min(chunk, skv)
    kv_pad = (-skv) % kc
    if kv_pad:  # non-multiple KV length (e.g. whisper's 1500-frame encoder)
        kv_k = jnp.pad(kv_k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        kv_v = jnp.pad(kv_v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    n_q, n_k = sq // qc, (skv + kv_pad) // kc
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    kv_ks = kv_k.reshape(b, n_k, kc, kh, dh).swapaxes(0, 1)
    kv_vs = kv_v.reshape(b, n_k, kc, kh, dh).swapaxes(0, 1)

    @jax.checkpoint
    def q_chunk_body(qi, q_c):
        q32 = q_c.astype(jnp.float32)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_body(carry, xs):
            ki, k_c, v_c = xs
            k_pos = ki * kc + jnp.arange(kc)
            logits = jnp.einsum("bqkgd,bckd->bqkgc", q32,
                                k_c.astype(jnp.float32)) * scale
            allow = _mask(q_pos, k_pos, causal, window, prefix_len,
                          k_valid=k_pos < skv)
            logits = jnp.where(allow[None, :, None, None, :], logits, NEG_INF)
            return _online_update(carry, logits, v_c), None

        init = (
            jnp.full((b, qc, kh, g), NEG_INF, jnp.float32),
            jnp.zeros((b, qc, kh, g), jnp.float32),
            jnp.zeros((b, qc, kh, g, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_body, init, (jnp.arange(n_k), kv_ks, kv_vs)
        )
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    qs = q.reshape(b, n_q, qc, kh, g, dh).swapaxes(0, 1)
    out = jax.lax.map(lambda xs: q_chunk_body(xs[0], xs[1]),
                      (jnp.arange(n_q), qs))
    return out.swapaxes(0, 1).reshape(b, sq, kh, g, dh)


def _kv_chunked_core(
    q,  # (B, Sq, K, G, Dh)
    kv_k,
    kv_v,  # (B, Skv, K, Dh)
    q_offset: int,
    causal: bool,
    window: Optional[int],
    prefix_len: Optional[int],
    chunk: int = _DEFAULT_CHUNK,
) -> jax.Array:
    """Online-softmax over KV chunks with the FULL q kept as one tensor.

    Unlike ``_chunked_core`` this never slices the sequence dim of q, so a
    sequence-parallel sharding of q survives the whole computation — the
    scan-over-q-chunks variant would dynamic-slice a sharded dim, which
    GSPMD resolves by replicating every chunk (16x waste). Used when q is
    seq-sharded (prefill of archs whose head count cannot shard over
    'model'). Memory is O(Sq_local * chunk) for the logits of one kv step.
    Causal upper-triangle blocks are masked, not skipped (XLA fallback; the
    Pallas kernel skips them on real TPUs).
    """
    b, sq, kh, g, dh = q.shape
    skv = kv_k.shape[1]
    kc = min(chunk, skv)
    kv_pad = (-skv) % kc
    if kv_pad:
        kv_k = jnp.pad(kv_k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        kv_v = jnp.pad(kv_v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    n_k = (skv + kv_pad) // kc
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    kv_ks = kv_k.reshape(b, n_k, kc, kh, dh).swapaxes(0, 1)
    kv_vs = kv_v.reshape(b, n_k, kc, kh, dh).swapaxes(0, 1)

    @jax.checkpoint
    def kv_body(carry, xs):
        ki, k_c, v_c = xs
        k_pos = ki * kc + jnp.arange(kc)
        logits = jnp.einsum(
            "bqkgd,bckd->bqkgc", q32, k_c.astype(jnp.float32)
        ) * scale
        allow = _mask(q_pos, k_pos, causal, window, prefix_len,
                      k_valid=k_pos < skv)
        logits = jnp.where(allow[None, :, None, None, :], logits, NEG_INF)
        return _online_update(carry, logits, v_c), None

    init = (
        jnp.full((b, sq, kh, g), NEG_INF, jnp.float32),
        jnp.zeros((b, sq, kh, g), jnp.float32),
        jnp.zeros((b, sq, kh, g, dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        kv_body, init, (jnp.arange(n_k), kv_ks, kv_vs)
    )
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def _banded_core(
    q,  # (B, Sq, K, G, Dh)
    kv_k,
    kv_v,
    q_offset: int,
    window: int,
    chunk: int = _DEFAULT_CHUNK,
) -> jax.Array:
    """Sliding-window attention via one static KV band per Q chunk.

    For Q chunk starting at s, only positions [s - window + 1, s + qc) can be
    attended; we dynamic-slice a band of width (window + qc) and run a dense
    masked core on it: flops O(Sq * (window + chunk)) instead of O(Sq * Skv).
    """
    b, sq, kh, g, dh = q.shape
    skv = kv_k.shape[1]
    qc = _divisor_chunk(sq, chunk)
    n_q = sq // qc
    band = min(window + qc, skv)

    @jax.checkpoint
    def q_chunk_body(qi, q_c):
        start_q = qi * qc
        band_start = jnp.clip(start_q + q_offset - window + 1, 0, skv - band)
        k_band = jax.lax.dynamic_slice_in_dim(kv_k, band_start, band, axis=1)
        v_band = jax.lax.dynamic_slice_in_dim(kv_v, band_start, band, axis=1)
        q_pos = q_offset + start_q + jnp.arange(qc)
        k_pos = band_start + jnp.arange(band)
        allow = _mask(q_pos, k_pos, True, window, None)
        return _dense_core(q_c, k_band, v_band, allow)

    qs = q.reshape(b, n_q, qc, kh, g, dh).swapaxes(0, 1)
    out = jax.lax.map(lambda xs: q_chunk_body(xs[0], xs[1]),
                      (jnp.arange(n_q), qs))
    return out.swapaxes(0, 1).reshape(b, sq, kh, g, dh)


# ---- block application ------------------------------------------------------------


def _project_qkv(cfg, p, x, kv_x=None):
    """x (B,S,D) -> q (B,S,H,Dh), k/v (B,Skv,K,Dh). Weights are flat."""
    h, k_heads, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kv_x = x if kv_x is None else kv_x
    q = x @ p["wq"].astype(x.dtype)
    k = kv_x @ p["wk"].astype(x.dtype)
    v = kv_x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    b, s = x.shape[0], x.shape[1]
    skv = kv_x.shape[1]
    return (
        q.reshape(b, s, h, dh),
        k.reshape(b, skv, k_heads, dh),
        v.reshape(b, skv, k_heads, dh),
    )


def _group(q, n_kv):
    b, s, h, dh = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, dh)


def _ungroup(o):
    b, s, k, g, dh = o.shape
    return o.reshape(b, s, k * g, dh)


def attn_apply(
    cfg: ArchConfig,
    spec: BlockSpec,
    p: PyTree,
    x: jax.Array,
    *,
    policy: ShardingPolicy,
    positions: Optional[jax.Array] = None,
    cache: Optional[PyTree] = None,
    decode_pos: Optional[jax.Array] = None,
    prefix_len: Optional[int] = None,
    cross_kv: Optional[jax.Array] = None,
    causal: bool = True,
    chunk: int = _DEFAULT_CHUNK,
) -> tuple[jax.Array, Optional[PyTree]]:
    """One attention block. Returns (out, new_cache).

    Modes:
      * train/prefill: ``cache is None`` (train) or cache returned filled
        (prefill): full-sequence x, chunked/banded cores.
      * decode: ``decode_pos`` given, x is (B, 1, D), cache is read+updated.
      * cross: ``cross_kv`` is the encoder output (B, Senc, D); no cache
        mutation (cross KV is precomputed into the cache at prefill).
    """
    b, s, d = x.shape
    n_kv = cfg.n_kv_heads
    window = spec.window if spec.mixer is Mixer.LOCAL_ATTN else None

    if positions is None:
        base = 0 if decode_pos is None else decode_pos
        positions = base + jnp.arange(s)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))

    q, k, v = _project_qkv(cfg, p, x, kv_x=cross_kv)
    if spec.rope_base is not None and cross_kv is None:
        q = rope(q, positions, spec.rope_base)
        k = rope(k, positions, spec.rope_base)
    # inside attention, seq and heads cannot both take 'model': heads win
    # (Megatron layout — the seq constraint re-applies at the block output)
    q_seq_ax = None if policy.heads is not None else policy.seq
    q = policy.constrain(q, (policy.batch, q_seq_ax, policy.heads, None))
    # Head-sharded execution (train/prefill): repeat K/V up to the full head
    # count so every attention einsum has the same head dim — GSPMD then
    # pad-shards H over 'model' uniformly. Without this, the (K, G) grouped
    # layout forces an 8-way <-> 16-way reshard per einsum, which the SPMD
    # partitioner resolves by involuntary full rematerialization (replicating
    # whole activations). KV-cache layouts keep the un-repeated GQA K.
    k_cache_src, v_cache_src = k, v
    n_kv_eff = n_kv
    if (policy.heads is not None and n_kv < cfg.n_heads
            and decode_pos is None):
        g_rep = cfg.n_heads // n_kv
        k = jnp.repeat(k, g_rep, axis=2)
        v = jnp.repeat(v, g_rep, axis=2)
        n_kv_eff = cfg.n_heads
    if policy.heads is not None and decode_pos is None:
        k = policy.constrain(k, (policy.batch, None, policy.heads, None))
        v = policy.constrain(v, (policy.batch, None, policy.heads, None))
    qg = _group(q, n_kv_eff)

    def _flat(t):  # (B, L, K, Dh) -> cache layout (B, L, K*Dh)
        return t.reshape(t.shape[0], t.shape[1], -1)

    def _unflat(t):  # cache layout -> (B, L, K, Dh)
        return t.reshape(t.shape[0], t.shape[1], n_kv, cfg.resolved_head_dim)

    new_cache = cache
    if decode_pos is not None and cache is not None:
        # -- decode: write k/v at decode_pos (ring for local), dense core
        cache_len = cache["k"].shape[1]
        if window is not None:
            slot = decode_pos % cache_len
        else:
            slot = decode_pos
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], _flat(k), slot, axis=1
        )
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], _flat(v), slot, axis=1
        )
        new_cache = {"k": ck, "v": cv}
        idx = jnp.arange(cache_len)
        if window is not None:
            # slot s holds absolute position p = decode_pos - ((decode_pos - s) mod L)
            k_pos = decode_pos - jnp.mod(decode_pos - idx, cache_len)
            k_valid = k_pos >= 0
        else:
            k_pos = idx
            k_valid = idx <= decode_pos
        allow = _mask(
            positions[0], k_pos, causal, window, prefix_len, k_valid
        )
        out = _dense_core(qg, _unflat(ck), _unflat(cv), allow)
    elif cross_kv is not None:
        if s <= _DENSE_MAX_SEQ:
            allow = jnp.ones((s, cross_kv.shape[1]), bool)
            out = _dense_core(qg, k, v, allow)
        else:
            # long decoder sequences: chunked core, non-causal, no mask —
            # keeps cross-attn memory O(chunk * Senc) instead of O(Sq * Senc)
            out = _chunked_core(qg, k, v, 0, False, None, None, chunk)
    else:
        # -- train / prefill over the full sequence
        if cache is not None:  # prefill: persist computed K/V (GQA layout)
            k_w, v_w = k_cache_src, v_cache_src
            cache_len = cache["k"].shape[1]
            if window is not None and s > cache_len:
                # ring buffer keeps the LAST `cache_len` positions
                tail_k, tail_v = _flat(k_w)[:, -cache_len:], _flat(v_w)[:, -cache_len:]
                # place position p at slot p % cache_len
                pos_tail = jnp.arange(s - cache_len, s)
                slots = jnp.mod(pos_tail, cache_len)
                ck = jnp.zeros_like(cache["k"]).at[:, slots].set(tail_k)
                cv = jnp.zeros_like(cache["v"]).at[:, slots].set(tail_v)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], _flat(k_w)[:, :cache_len], 0, axis=1
                )
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], _flat(v_w)[:, :cache_len], 0, axis=1
                )
            new_cache = {"k": ck, "v": cv}
        if window is not None and s > window:
            out = _banded_core(qg, k, v, 0, window, chunk)
        elif s <= _DENSE_MAX_SEQ:
            allow = _mask(
                jnp.arange(s), jnp.arange(s), causal, window, prefix_len
            )
            out = _dense_core(qg, k, v, allow)
        elif policy.heads is None and policy.seq is not None:
            # q is sequence-sharded and heads cannot take the 'model' axis:
            # the q-chunk scan would slice a sharded dim (replication) —
            # keep q whole and stream KV chunks instead
            out = _kv_chunked_core(qg, k, v, 0, causal, window, prefix_len,
                                   chunk)
        else:
            out = _chunked_core(qg, k, v, 0, causal, window, prefix_len, chunk)

    o = _ungroup(out)  # (B, S, H, Dh)
    o_flat = o.reshape(o.shape[0], o.shape[1], -1)
    y = o_flat @ p["wo"].astype(x.dtype)
    # Megatron-SP: reduce-scatter the TP-partial output back onto the
    # sequence axis (GSPMD emits it from this constraint pair)
    y = policy.constrain(y, (policy.batch, policy.seq, None))
    return y.astype(x.dtype), new_cache
