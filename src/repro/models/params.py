"""Parameter definition system: shapes + sharding + init, declared once.

Every model layer declares its parameters as a pytree of ``ParamDef`` — a
(shape, dtype, sharding-spec, init-kind) record. From one declaration we
derive three consistent views:

* ``to_struct``  — ShapeDtypeStruct tree (allocation-free; the dry-run path)
* ``to_specs``   — PartitionSpec tree for in_shardings
* ``materialize``— real arrays (smoke tests / real training)

This guarantees the dry-run's sharding config and the runnable model can
never drift apart — the recurring failure mode of hand-maintained sharding
tables in large frameworks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any

# Mesh axis names used across the framework (launch/mesh.py builds meshes
# with exactly these): optional leading "pod", then "data", "model".
AxisName = Optional[str | tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter tensor: shape, dtype, per-dim mesh axes, init style."""

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    axes: tuple[AxisName, ...] = ()  # len == len(shape); None = replicated
    init: str = "normal"  # normal | zeros | ones | scaled(fan-in)
    init_scale: float = 1.0

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank"
            )

    @property
    def spec(self) -> P:
        axes = self.axes if self.axes else (None,) * len(self.shape)
        return P(*axes)

    @property
    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def to_struct(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda d: d.struct, defs, is_leaf=is_def)


def to_specs(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=is_def)


def drop_axis(defs: PyTree, axis: str) -> PyTree:
    """Remove one mesh axis from every spec (e.g. disable FSDP: drop 'data')."""

    def leaf(d: ParamDef) -> ParamDef:
        def clean(a: AxisName) -> AxisName:
            if a == axis:
                return None
            if isinstance(a, tuple):
                kept = tuple(x for x in a if x != axis)
                return kept if len(kept) > 1 else (kept[0] if kept else None)
            return a

        axes = tuple(clean(a) for a in (d.axes or (None,) * len(d.shape)))
        return dataclasses.replace(d, axes=axes)

    return jax.tree.map(leaf, defs, is_leaf=is_def)


def stack(defs: PyTree, n: int) -> PyTree:
    """Prepend a scan/layers axis of size ``n`` (replicated) to every def."""

    def leaf(d: ParamDef) -> ParamDef:
        axes = d.axes if d.axes else (None,) * len(d.shape)
        return dataclasses.replace(
            d, shape=(n,) + d.shape, axes=(None,) + tuple(axes)
        )

    return jax.tree.map(leaf, defs, is_leaf=is_def)


def materialize(defs: PyTree, key: jax.Array) -> PyTree:
    """Real arrays for every def, fan-in-scaled normal by default."""
    flat, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, max(len(flat), 1))

    def one(d: ParamDef, k: jax.Array) -> jax.Array:
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        # fan-in scaling over the last-but-one dim (or last for 1-D)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
        scale = d.init_scale / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
        return (scale * jax.random.normal(k, d.shape, jnp.float32)).astype(d.dtype)

    return jax.tree.unflatten(treedef, [one(d, k) for d, k in zip(flat, keys)])


def count_params(defs: PyTree) -> int:
    flat = jax.tree.leaves(defs, is_leaf=is_def)
    total = 0
    for d in flat:
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total


def param_bytes(defs: PyTree) -> int:
    flat = jax.tree.leaves(defs, is_leaf=is_def)
    total = 0
    for d in flat:
        n = 1
        for s in d.shape:
            n *= s
        total += n * jnp.dtype(d.dtype).itemsize
    return total
