"""The composable LM stack: scan-over-superblocks transformer assembly.

One class drives all ten assigned architectures: dense decoders (phi4, qwen,
starcoder2), local:global patterns (gemma3), MoE (mixtral, phi3.5-moe),
hybrid recurrent (recurrentgemma), xLSTM stacks, encoder-decoder (whisper)
and VLM-prefix models (paligemma). The depth dimension lowers as one
``lax.scan`` per (superblock, repeats) group, so HLO size — and therefore
512-device compile time — is O(pattern length), not O(depth).

API (all pure functions over pytrees):
  * ``param_defs()`` / ``init(key)`` / ``cache_defs(batch, max_len)``
  * ``train_loss(params, batch)``             -> (loss, metrics)
  * ``prefill(params, cache, batch)``         -> (last_logits, cache)
  * ``decode_step(params, cache, batch, pos)``-> (logits, cache)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import params as pdefs
from repro.models.attention import (
    ShardingPolicy,
    attn_apply,
    attn_defs,
    cache_defs as attn_cache_defs,
)
from repro.models.config import ArchConfig, BlockSpec, FF, Mixer
from repro.models.layers import (
    adt,
    chunked_softmax_xent,
    embed_apply,
    embed_defs,
    ff_apply,
    ff_defs,
    norm_apply,
    norm_defs,
    sinusoidal_positions,
    unembed_apply,
)
from repro.models.moe import moe_apply, moe_defs
from repro.models.rglru import rglru_apply, rglru_cache_defs, rglru_defs
from repro.models.xlstm import (
    mlstm_apply,
    mlstm_cache_defs,
    mlstm_defs,
    slstm_apply,
    slstm_cache_defs,
    slstm_defs,
)

PyTree = Any

_ATTN_MIXERS = (Mixer.GLOBAL_ATTN, Mixer.LOCAL_ATTN, Mixer.CROSS_ATTN)


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ArchConfig
    policy: ShardingPolicy = dataclasses.field(default_factory=ShardingPolicy)

    # ---- parameter declaration ------------------------------------------------

    def _block_defs(self, spec: BlockSpec) -> PyTree:
        cfg = self.cfg
        d: dict[str, Any] = {"norm1": norm_defs(cfg)}
        if spec.mixer in _ATTN_MIXERS:
            d["mixer"] = attn_defs(cfg, cross=spec.mixer is Mixer.CROSS_ATTN)
        elif spec.mixer is Mixer.RGLRU:
            d["mixer"] = rglru_defs(cfg)
        elif spec.mixer is Mixer.MLSTM:
            d["mixer"] = mlstm_defs(cfg)
        elif spec.mixer is Mixer.SLSTM:
            d["mixer"] = slstm_defs(cfg)
        else:
            raise ValueError(spec.mixer)
        if spec.ff is FF.MOE:
            d["norm2"] = norm_defs(cfg)
            d["ff"] = moe_defs(cfg)
        elif spec.ff is not FF.NONE:
            d["norm2"] = norm_defs(cfg)
            d["ff"] = ff_defs(cfg, spec.ff)
        return d

    def _superblock_defs(self, superblock: tuple[BlockSpec, ...]) -> PyTree:
        return {f"b{i}": self._block_defs(s) for i, s in enumerate(superblock)}

    def param_defs(self) -> PyTree:
        cfg = self.cfg
        defs: dict[str, Any] = {"embed": embed_defs(cfg)}
        defs["groups"] = [
            pdefs.stack(self._superblock_defs(sb), reps)
            for sb, reps in cfg.groups
        ]
        defs["final_norm"] = norm_defs(cfg)
        if cfg.encoder is not None and cfg.family == "audio":
            # whisper-style audio encoder: full bidirectional attention
            enc_sb = (BlockSpec(Mixer.GLOBAL_ATTN, FF.GELU, rope_base=None),)
            defs["encoder"] = {
                "groups": [
                    pdefs.stack(self._superblock_defs(enc_sb), cfg.encoder.n_layers)
                ],
                "final_norm": norm_defs(cfg),
            }
        return defs

    def init(self, key: jax.Array) -> PyTree:
        return pdefs.materialize(self.param_defs(), key)

    def n_params(self) -> int:
        return pdefs.count_params(self.param_defs())

    def n_active_params(self) -> int:
        """MoE-aware active parameter count (for MODEL_FLOPS = 6*N_active*D)."""
        cfg = self.cfg
        total = self.n_params()
        if cfg.moe is None:
            return total
        moe_total = 0
        moe_active = 0
        for sb, reps in cfg.groups:
            for s in sb:
                if s.ff is FF.MOE:
                    per_expert = 3 * cfg.d_model * cfg.d_ff
                    moe_total += reps * cfg.moe.n_experts * per_expert
                    moe_active += reps * cfg.moe.top_k * per_expert
        return total - moe_total + moe_active

    # ---- caches -----------------------------------------------------------------

    def _block_cache_defs(
        self, spec: BlockSpec, batch: int, max_len: int
    ) -> Optional[PyTree]:
        cfg, pol = self.cfg, self.policy
        if spec.mixer is Mixer.CROSS_ATTN:
            return None  # cross K/V recomputed from encoder_out each step
        if spec.mixer in _ATTN_MIXERS:
            return attn_cache_defs(cfg, spec, batch, max_len, pol)
        if spec.mixer is Mixer.RGLRU:
            return rglru_cache_defs(cfg, batch, pol)
        if spec.mixer is Mixer.MLSTM:
            return mlstm_cache_defs(cfg, batch, pol)
        if spec.mixer is Mixer.SLSTM:
            return slstm_cache_defs(cfg, batch, pol)
        return None

    def cache_defs(self, batch: int, max_len: int) -> PyTree:
        groups = []
        for sb, reps in self.cfg.groups:
            sub = {}
            for i, s in enumerate(sb):
                cd = self._block_cache_defs(s, batch, max_len)
                if cd is not None:
                    sub[f"b{i}"] = pdefs.stack(cd, reps)
            groups.append(sub)
        return {"groups": groups}

    def init_cache(self, batch: int, max_len: int) -> PyTree:
        defs = self.cache_defs(batch, max_len)
        return jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype), defs, is_leaf=pdefs.is_def
        )

    # ---- block application --------------------------------------------------------

    def _apply_block(
        self,
        spec: BlockSpec,
        p: PyTree,
        x: jax.Array,
        cache: Optional[PyTree],
        *,
        decode_pos: Optional[jax.Array],
        prefix_len: Optional[int],
        encoder_out: Optional[jax.Array],
        causal: bool,
    ) -> tuple[jax.Array, jax.Array, Optional[PyTree]]:
        cfg, pol = self.cfg, self.policy
        aux = jnp.zeros((), jnp.float32)
        h = norm_apply(cfg, p["norm1"], x)
        if spec.mixer in _ATTN_MIXERS:
            mixed, new_cache = attn_apply(
                cfg, spec, p["mixer"], h,
                policy=pol,
                cache=cache,
                decode_pos=decode_pos,
                prefix_len=prefix_len,
                cross_kv=encoder_out if spec.mixer is Mixer.CROSS_ATTN else None,
                causal=causal,
            )
        elif spec.mixer is Mixer.RGLRU:
            mixed, new_cache = rglru_apply(
                cfg, p["mixer"], h, cache, decode=decode_pos is not None,
                policy=pol,
            )
        elif spec.mixer is Mixer.MLSTM:
            mixed, new_cache = mlstm_apply(
                cfg, p["mixer"], h, cache, decode=decode_pos is not None,
                policy=pol,
            )
        elif spec.mixer is Mixer.SLSTM:
            mixed, new_cache = slstm_apply(
                cfg, p["mixer"], h, cache, decode=decode_pos is not None,
                policy=pol,
            )
        else:
            raise ValueError(spec.mixer)
        x = x + mixed

        if spec.ff is FF.MOE:
            h2 = norm_apply(cfg, p["norm2"], x)
            ff_out, aux = moe_apply(cfg, p["ff"], h2, policy=pol)
            x = x + ff_out
        elif spec.ff is not FF.NONE:
            h2 = norm_apply(cfg, p["norm2"], x)
            x = x + ff_apply(cfg, spec.ff, p["ff"], h2)
        return x, aux, new_cache

    def _run_group(
        self,
        superblock: tuple[BlockSpec, ...],
        group_params: PyTree,
        x: jax.Array,
        group_cache: Optional[PyTree],
        **kw,
    ) -> tuple[jax.Array, jax.Array, Optional[PyTree]]:
        """Scan `reps` copies of the superblock over the residual stream."""
        has_cache = group_cache is not None and len(group_cache) > 0

        pol = self.policy

        @partial(jax.checkpoint, static_argnums=())
        def superblock_fwd(xc, p_sb, c_sb):
            """One superblock; rematerialized in the backward pass so the
            scan saves only the (SP-sharded) residual-stream carry per rep —
            O(depth * B*S*D / (dp*tp)) activation memory (DESIGN.md §5)."""
            aux_acc = jnp.zeros((), jnp.float32)
            new_caches = {}
            for i, spec in enumerate(superblock):
                c_in = c_sb.get(f"b{i}") if has_cache else None
                xc, aux, c_out = self._apply_block(
                    spec, p_sb[f"b{i}"], xc, c_in, **kw
                )
                aux_acc = aux_acc + aux
                if c_out is not None and has_cache:
                    new_caches[f"b{i}"] = c_out
            # re-pin the carry to the sequence-parallel layout at the
            # superblock boundary (keeps the scan carry small per chip)
            xc = pol.constrain(xc, (pol.batch, pol.seq, None))
            return xc, aux_acc, new_caches

        def body(carry, xs):
            xc, aux_acc = carry
            p_sb, c_sb = xs if has_cache else (xs, {})
            xc, aux, new_caches = superblock_fwd(xc, p_sb, c_sb or {})
            return (xc, aux_acc + aux), (new_caches if has_cache else None)

        xs = (group_params, group_cache) if has_cache else group_params
        (x, aux), new_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs
        )
        return x, aux, (new_cache if has_cache else None)

    # ---- full forward ---------------------------------------------------------------

    def _encode(self, params: PyTree, frames: jax.Array) -> jax.Array:
        """Whisper audio encoder over precomputed frame embeddings (stub
        frontend): sinusoidal positions + bidirectional attention stack."""
        cfg = self.cfg
        enc = params["encoder"]
        pos = sinusoidal_positions(frames.shape[1], cfg.d_model)
        x = (frames.astype(jnp.float32) + pos[None]).astype(adt(cfg))
        enc_sb = (BlockSpec(Mixer.GLOBAL_ATTN, FF.GELU, rope_base=None),)
        x, _, _ = self._run_group(
            enc_sb, enc["groups"][0], x, None,
            decode_pos=None, prefix_len=None, encoder_out=None, causal=False,
        )
        return norm_apply(cfg, enc["final_norm"], x)

    def forward(
        self,
        params: PyTree,
        tokens: jax.Array,
        *,
        cache: Optional[PyTree] = None,
        decode_pos: Optional[jax.Array] = None,
        encoder_out: Optional[jax.Array] = None,
        vision_embeds: Optional[jax.Array] = None,
    ) -> tuple[jax.Array, Optional[PyTree], jax.Array]:
        """Returns (hidden (B,S,d), new_cache, moe_aux_loss)."""
        cfg = self.cfg
        x = embed_apply(cfg, params["embed"], tokens)
        prefix_len = None
        if vision_embeds is not None:  # paligemma: prepend patch embeddings
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
            prefix_len = vision_embeds.shape[1] if cfg.prefix_lm else None
        x = self.policy.constrain(
            x, (self.policy.batch, self.policy.seq, None)
        )

        new_groups = []
        aux_total = jnp.zeros((), jnp.float32)
        cache_groups = cache["groups"] if cache is not None else None
        for gi, (sb, reps) in enumerate(cfg.groups):
            gc = cache_groups[gi] if cache_groups is not None else None
            x, aux, ngc = self._run_group(
                sb, params["groups"][gi], x, gc,
                decode_pos=decode_pos,
                prefix_len=prefix_len,
                encoder_out=encoder_out,
                causal=True,
            )
            aux_total = aux_total + aux
            new_groups.append(ngc if ngc is not None else (gc or {}))
        x = norm_apply(cfg, params["final_norm"], x)
        new_cache = {"groups": new_groups} if cache is not None else None
        return x, new_cache, aux_total

    # ---- entry points ------------------------------------------------------------------

    def train_loss(
        self, params: PyTree, batch: dict[str, jax.Array]
    ) -> tuple[jax.Array, dict[str, jax.Array]]:
        cfg = self.cfg
        encoder_out = None
        if cfg.family == "audio":
            encoder_out = self._encode(params, batch["frames"])
        vision = batch.get("vision_embeds")
        hidden, _, aux = self.forward(
            params, batch["tokens"], encoder_out=encoder_out,
            vision_embeds=vision,
        )
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if vision is not None:
            hidden = hidden[:, vision.shape[1] :]  # loss over text positions only
        loss = chunked_softmax_xent(cfg, params["embed"], hidden, labels, mask)
        total = loss + 0.01 * aux
        return total, {"xent": loss, "moe_aux": aux}

    def prefill(
        self, params: PyTree, cache: PyTree, batch: dict[str, jax.Array]
    ) -> tuple[jax.Array, PyTree]:
        """Fill the KV/recurrent caches from a full prompt; return logits of
        the last position (next-token distribution) and the filled cache."""
        cfg = self.cfg
        encoder_out = None
        if cfg.family == "audio":
            encoder_out = self._encode(params, batch["frames"])
        hidden, new_cache, _ = self.forward(
            params, batch["tokens"], cache=cache, encoder_out=encoder_out,
            vision_embeds=batch.get("vision_embeds"),
        )
        logits = unembed_apply(cfg, params["embed"], hidden[:, -1:])
        return logits, new_cache

    def decode_step(
        self,
        params: PyTree,
        cache: PyTree,
        batch: dict[str, jax.Array],
        pos: jax.Array,
    ) -> tuple[jax.Array, PyTree]:
        """One-token decode: batch['tokens'] is (B, 1); pos is the absolute
        position being written (scalar int32)."""
        cfg = self.cfg
        encoder_out = batch.get("encoder_out")
        if cfg.family == "audio" and encoder_out is None:
            encoder_out = self._encode(params, batch["frames"])
        hidden, new_cache, _ = self.forward(
            params, batch["tokens"], cache=cache, decode_pos=pos,
            encoder_out=encoder_out,
        )
        logits = unembed_apply(cfg, params["embed"], hidden)
        return logits, new_cache
