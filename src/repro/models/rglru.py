"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> {gelu gate branch, linear branch -> temporal conv(4) -> RG-LRU}
-> elementwise product -> down projection.

RG-LRU recurrence (fp32):
    r_t = sigmoid(W_r xi_t + b_r)          # recurrence gate
    i_t = sigmoid(W_i xi_t + b_i)          # input gate
    a_t = exp(c * r_t * log(sigmoid(lam))) # per-channel decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

Training runs the recurrence as one ``jax.lax.associative_scan`` over the
sequence (log-depth, parallel — the TPU-native adaptation of the paper's
linear-scan CUDA kernel); decode carries (h, conv window) state.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.params import ParamDef

PyTree = Any

_CONV_W = 4
_C_EXP = 8.0


def rglru_defs(cfg: ArchConfig) -> PyTree:
    d = cfg.d_model
    dr = d  # d_rnn = d_model (Griffin uses ~d; keep square)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_gate": ParamDef((d, dr), dt, ("data", "model")),
        "w_x": ParamDef((d, dr), dt, ("data", "model")),
        "conv": ParamDef((_CONV_W, dr), dt, (None, "model"), init_scale=0.5),
        "w_r": ParamDef((dr, dr), dt, ("data", "model")),
        "b_r": ParamDef((dr,), jnp.float32, ("model",), "zeros"),
        "w_i": ParamDef((dr, dr), dt, ("data", "model")),
        "b_i": ParamDef((dr,), jnp.float32, ("model",), "zeros"),
        "lam": ParamDef((dr,), jnp.float32, ("model",), "zeros"),
        "w_out": ParamDef((dr, d), dt, ("model", "data")),
    }


def rglru_cache_defs(cfg: ArchConfig, batch: int, policy) -> PyTree:
    dr = cfg.d_model
    bax = policy.batch if batch > 1 else None
    return {
        "h": ParamDef((batch, dr), jnp.float32, (bax, "model"), "zeros"),
        "conv_buf": ParamDef(
            (batch, _CONV_W - 1, dr), jnp.dtype(cfg.activation_dtype),
            (bax, None, "model"), "zeros",
        ),
    }


def _gates(p: PyTree, xi: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(a_t, gated input) in fp32. xi: (..., dr)."""
    xf = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_r"].astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a_base = jax.nn.log_sigmoid(p["lam"] + 4.0)  # init ~= 0.982 decay
    a = jnp.exp(_C_EXP * r * log_a_base)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-9)) * (i * xf)
    return a, b


def _conv(p: PyTree, xl: jax.Array, buf: Optional[jax.Array]) -> jax.Array:
    """Causal temporal conv, width 4. xl: (B, S, dr)."""
    w = p["conv"].astype(jnp.float32)  # (4, dr)
    if buf is None:
        pad = jnp.zeros((xl.shape[0], _CONV_W - 1, xl.shape[-1]), xl.dtype)
    else:
        pad = buf.astype(xl.dtype)
    xp = jnp.concatenate([pad, xl], axis=1).astype(jnp.float32)
    out = sum(
        w[j][None, None, :] * xp[:, j : j + xl.shape[1]] for j in range(_CONV_W)
    )
    return out.astype(xl.dtype)


def rglru_apply(
    cfg: ArchConfig,
    p: PyTree,
    x: jax.Array,
    cache: Optional[PyTree] = None,
    decode: bool = False,
    policy=None,  # rg-lru's associative scan needs no carry constraint
) -> tuple[jax.Array, Optional[PyTree]]:
    """x: (B, S, d). Returns (out, new_cache)."""
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
    xl = x @ p["w_x"].astype(x.dtype)

    if decode:
        assert cache is not None and x.shape[1] == 1
        xc = _conv(p, xl, cache["conv_buf"])  # (B, 1, dr)
        a, b = _gates(p, xc[:, 0])
        h = a * cache["h"] + b  # (B, dr) fp32
        new_cache = {
            "h": h,
            "conv_buf": jnp.concatenate(
                [cache["conv_buf"][:, 1:], xl], axis=1
            ).astype(cache["conv_buf"].dtype),
        }
        y = h[:, None, :].astype(x.dtype)
    else:
        xc = _conv(p, xl, None)
        a, b = _gates(p, xc)  # (B, S, dr) each

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        a_acc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache = None
        if cache is not None:  # prefill: persist final state + conv tail
            new_cache = {
                "h": h[:, -1],
                "conv_buf": xl[:, -(_CONV_W - 1) :].astype(
                    cache["conv_buf"].dtype
                ),
            }
        y = h.astype(x.dtype)

    out = (gate * y) @ p["w_out"].astype(x.dtype)
    return out.astype(x.dtype), new_cache
