"""Logistic regression — dense and sparse (hashing trick), paper §6.1.

The paper trains LR on Criteo in two forms: *dense* (13 numerical features)
and *sparse* (26 categorical features hashed into a 1e5-dim space plus the 13
numericals). Sparse minibatches are carried in a fixed-width COO-style layout
``(indices, values)`` per sample so everything jits with static shapes — this
mirrors MLLess's Cython sparse structures, adapted to TPU-friendly dense
index arrays + one-hot-free segment ops.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LRConfig:
    n_features: int  # 13 for dense-Criteo, 100_013 for sparse-Criteo
    l2: float = 0.0
    sparse: bool = False
    nnz_per_sample: int = 39  # 13 numerical + 26 hashed categoricals


class LRParams(NamedTuple):
    w: jax.Array  # (n_features,)
    b: jax.Array  # ()


def init(config: LRConfig, key: jax.Array) -> LRParams:
    w = 0.01 * jax.random.normal(key, (config.n_features,), jnp.float32)
    return LRParams(w=w, b=jnp.zeros((), jnp.float32))


class DenseBatch(NamedTuple):
    x: jax.Array  # (B, n_features) float32
    y: jax.Array  # (B,) float32 in {0,1}


class SparseBatch(NamedTuple):
    """Fixed-width sparse rows: idx/val padded to nnz_per_sample with idx=0,val=0."""

    idx: jax.Array  # (B, nnz) int32
    val: jax.Array  # (B, nnz) float32
    y: jax.Array  # (B,) float32


def _logits_dense(params: LRParams, x: jax.Array) -> jax.Array:
    return x @ params.w + params.b


def _logits_sparse(params: LRParams, idx: jax.Array, val: jax.Array) -> jax.Array:
    # gather weights at the nonzero coordinates: (B, nnz)
    return jnp.sum(params.w[idx] * val, axis=-1) + params.b


def bce_loss(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Binary cross-entropy (the paper's LR convergence metric)."""
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def loss_fn(config: LRConfig, params: LRParams, batch) -> jax.Array:
    if config.sparse:
        logits = _logits_sparse(params, batch.idx, batch.val)
    else:
        logits = _logits_dense(params, batch.x)
    loss = bce_loss(logits, batch.y)
    if config.l2:
        loss = loss + 0.5 * config.l2 * jnp.sum(jnp.square(params.w))
    return loss


def grad_fn(config: LRConfig, params: LRParams, batch):
    """(loss, grads). Sparse grads are naturally sparse — only coordinates
    touched by the minibatch are nonzero (the paper's 'intrinsic filter')."""
    return jax.value_and_grad(lambda p: loss_fn(config, p, batch))(params)


def accuracy(config: LRConfig, params: LRParams, batch) -> jax.Array:
    if config.sparse:
        logits = _logits_sparse(params, batch.idx, batch.val)
    else:
        logits = _logits_dense(params, batch.x)
    return jnp.mean(((logits > 0).astype(jnp.float32) == batch.y).astype(jnp.float32))
