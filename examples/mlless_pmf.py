"""The paper's headline experiment in miniature (Fig. 7/8): PMF on a
MovieLens-like dataset, comparing three platforms:

  * MLLess (+ ISP + auto-tuner)  — specialized serverless (the paper)
  * serverful                    — PyTorch-like ring all-reduce on IaaS VMs
  * PyWren                       — non-specialized serverless (COS exchange)

Losses are REAL (the model genuinely trains); platform wall-clock and cost
come from the calibrated timing/billing model (core/billing.py, Table 2
prices). Prints time-to-loss and cost-to-loss per platform.

    PYTHONPATH=src python examples/mlless_pmf.py
"""

import sys

sys.path.insert(0, "src")

from functools import partial

import jax
import numpy as np

from repro import optim
from repro.core import consistency as cons
from repro.core.autotuner import AutoTunerConfig, ScaleInAutoTuner
from repro.core.isp import ISPConfig
from repro.core.simulator import Platform, ServerlessSimulator, SimulatorConfig
from repro.data import synthetic
from repro.models import pmf

P = 8          # workers
B = 2048       # per-worker minibatch (weak scaling keeps this fixed)
MAX_STEPS = 120
RMSE_TARGET = 0.95

ml = synthetic.MovieLensLikeConfig(n_users=2000, n_movies=4000,
                                   n_ratings=200_000, seed=0)
users, movies, ratings = synthetic.make_movielens(ml)
cfg = pmf.PMFConfig(n_users=ml.n_users, n_movies=ml.n_movies, rank=ml.rank)
params0 = pmf.init(cfg, jax.random.PRNGKey(0))
flops_per_sample = 6 * ml.rank * 3  # fwd+bwd on two rank-r rows

rng = np.random.default_rng(0)
eval_idx = rng.choice(len(ratings), 8192, replace=False)
eval_batch = synthetic.ratings_batch(users, movies, ratings, eval_idx)


def batch_fn(step: int, n_workers: int):
    r = np.random.default_rng(step)
    idx = r.integers(0, len(ratings), size=(n_workers, B))
    import jax.numpy as jnp

    return pmf.RatingsBatch(
        user=jnp.asarray(users[idx]),
        movie=jnp.asarray(movies[idx]),
        rating=jnp.asarray(ratings[idx]),
    )


def eval_fn(p):
    return float(pmf.rmse(p, eval_batch))


def run(platform: Platform, model: cons.Model, tuner: bool = False):
    sim = ServerlessSimulator(
        SimulatorConfig(
            n_workers=P,
            platform=platform,
            consistency=cons.ConsistencyConfig(
                model=model, isp=ISPConfig(v=0.7)
            ),
            sparse_model=True,
        ),
        grad_fn=partial(pmf.grad_fn, cfg),
        optimizer=optim.make("nesterov", 0.08),
        params=params0,
        flops_per_sample=flops_per_sample,
        update_nnz_fn=lambda bsz: 2 * ml.rank * min(bsz, ml.n_users),
    )
    t = (
        ScaleInAutoTuner(AutoTunerConfig(sched_interval_s=2.0, delta_s=1.0),
                         P)
        if tuner
        else None
    )
    res = sim.run(batch_fn, B, MAX_STEPS, loss_threshold=RMSE_TARGET,
                  eval_fn=eval_fn, tuner=t)
    return res


if __name__ == "__main__":
    jobs = [
        ("MLLess (BSP)", Platform.MLLESS, cons.Model.BSP, False),
        ("MLLess + ISP", Platform.MLLESS, cons.Model.ISP, False),
        ("MLLess + All", Platform.MLLESS, cons.Model.ISP, True),
        ("serverful (PyTorch-like)", Platform.SERVERFUL, cons.Model.BSP,
         False),
        ("PyWren-IBM-like", Platform.PYWREN, cons.Model.BSP, False),
    ]
    print(f"PMF rank={ml.rank}, target RMSE <= {RMSE_TARGET}, "
          f"P={P} workers x B={B}\n")
    print(f"{'system':28} {'time-to-loss':>13} {'cost $':>9} "
          f"{'final RMSE':>11} {'workers':>8}")
    for name, plat, model, tuner in jobs:
        r = run(plat, model, tuner)
        t = r.converged_at_s or r.total_wall_s
        mark = "" if r.converged_at_s else " (not conv.)"
        print(f"{name:28} {t:12.1f}s {r.total_cost:9.4f} "
              f"{r.final_loss:11.4f} {r.summary['final_workers']:8d}{mark}")
    print("\nExpected ordering (paper §6.3): MLLess+ISP+tuner fastest and "
          "cheapest;\nPyWren slowest; serverful cheap per-second but slow "
          "to converge.")
