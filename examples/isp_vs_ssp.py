"""SSP vs ISP for serverless training (paper §6.4, Fig. 9) in miniature.

Same PMF job under three consistency models at increasing worker counts,
with the global batch held constant (B = B_g / P — the paper's Table 3
protocol), so the statistical effect of staleness/filtering comes out
cleanly.

    PYTHONPATH=src python examples/isp_vs_ssp.py
"""

import sys

sys.path.insert(0, "src")

from functools import partial

import jax
import numpy as np

from repro import optim
from repro.core import consistency as cons
from repro.core.isp import ISPConfig
from repro.core.simulator import Platform, ServerlessSimulator, SimulatorConfig
from repro.data import synthetic
from repro.models import pmf

B_GLOBAL = 8192
MAX_STEPS = 100
RMSE_TARGET = 1.0

ml = synthetic.MovieLensLikeConfig(n_users=2000, n_movies=4000,
                                   n_ratings=200_000, seed=0)
users, movies, ratings = synthetic.make_movielens(ml)
cfg = pmf.PMFConfig(n_users=ml.n_users, n_movies=ml.n_movies, rank=ml.rank)
params0 = pmf.init(cfg, jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
eval_idx = rng.choice(len(ratings), 8192, replace=False)
eval_batch = synthetic.ratings_batch(users, movies, ratings, eval_idx)


def make_batch_fn(b_per_worker: int):
    def batch_fn(step: int, n_workers: int):
        import jax.numpy as jnp

        r = np.random.default_rng(step)
        idx = r.integers(0, len(ratings), size=(n_workers, b_per_worker))
        return pmf.RatingsBatch(
            user=jnp.asarray(users[idx]),
            movie=jnp.asarray(movies[idx]),
            rating=jnp.asarray(ratings[idx]),
        )

    return batch_fn


def run(P: int, model: cons.Model):
    b = B_GLOBAL // P
    sim = ServerlessSimulator(
        SimulatorConfig(
            n_workers=P,
            platform=Platform.MLLESS,
            consistency=cons.ConsistencyConfig(
                model=model, isp=ISPConfig(v=0.7), slack=3
            ),
            sparse_model=True,
        ),
        grad_fn=partial(pmf.grad_fn, cfg),
        optimizer=optim.make("nesterov", 0.08),
        params=params0,
        flops_per_sample=6 * ml.rank * 3,
        update_nnz_fn=lambda bsz: 2 * ml.rank * min(bsz, ml.n_users),
    )
    return sim.run(
        make_batch_fn(b), b, MAX_STEPS, loss_threshold=RMSE_TARGET,
        eval_fn=lambda p: float(pmf.rmse(p, eval_batch)),
    )


if __name__ == "__main__":
    print(f"PMF, fixed global batch {B_GLOBAL}, target RMSE {RMSE_TARGET} "
          f"(paper Fig. 9 protocol)\n")
    print(f"{'P':>3} {'model':>5} {'time-to-loss':>13} {'final RMSE':>11}")
    for P in (4, 8, 16):
        for model in (cons.Model.BSP, cons.Model.SSP, cons.Model.ISP):
            r = run(P, model)
            t = r.converged_at_s or r.total_wall_s
            mark = "" if r.converged_at_s else "*"
            print(f"{P:3d} {model.value:>5} {t:12.1f}s{mark} "
                  f"{r.final_loss:11.4f}")
    print("\n* did not reach the target within the step budget")
    print("Expected (paper §6.4): ISP beats SSP at every worker count — "
          "staleness\nwithout byte savings does not help when exchange cost "
          "dominates.")
