"""MLLess on real processes — the FaaS runtime quickstart (DESIGN.md §9).

Trains PMF (the paper's headline workload) on the multi-process serverless
runtime: 4 stateless worker processes exchange significance-filtered
updates through the in-memory broker, while the supervisor drives the
scale-in auto-tuner from *live* (loss, step-duration) telemetry and meters
real per-worker lifetimes at the 100 ms FaaS billing quantum.

Unlike ``mlless_pmf.py`` (simulator: modelled wall-clock), everything here
is measured: the step durations are real, the scale-in decisions happen on
a live loss curve, and the bill is computed from actual process lifetimes.

    PYTHONPATH=src python examples/mlless_faas.py              # ~1 min, CPU
    PYTHONPATH=src python examples/mlless_faas.py --steps 60 --no-check

Exits non-zero if the run fails its health checks (loss must decrease, the
auto-tuner must perform at least one live scale-in, the bill must come from
measured lifetimes) — CI runs this as the runtime smoke test.
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime import PMF_QUICKSTART_CFG, pmf_quickstart_config, run_job


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=140)
    ap.add_argument("--n-brokers", type=int, default=1,
                    help="update-store shards (one broker process each; "
                    "bills n_redis == n_brokers)")
    ap.add_argument("--transport", default="tcp", choices=("tcp", "shm"),
                    help="worker<->broker update path: loopback TCP or "
                    "zero-copy shared-memory rings (repro.wire.shm)")
    ap.add_argument("--consistency", default="isp", choices=("isp", "ssp"),
                    help="pull-barrier model: full per-step ISP barrier "
                    "(default) or bounded staleness (DESIGN.md §13)")
    ap.add_argument("--slack", type=int, default=3,
                    help="SSP staleness bound (ignored under isp)")
    ap.add_argument("--wire-impl", default="numpy",
                    choices=("numpy", "pallas", "auto"),
                    help="update-codec backend: numpy reference, the fused "
                    "Pallas encode/decode kernels (bit-identical bytes), "
                    "or per-leaf auto selection by size")
    ap.add_argument("--hostperf", action="store_true",
                    help="launch workers under the tuned host env "
                    "(launch/hostperf.py: tcmalloc preload when present, "
                    "pinned XLA host flags, thread caps)")
    ap.add_argument("--run-dir", default=None)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the health assertions (exploratory runs)")
    args = ap.parse_args()

    cfg = pmf_quickstart_config(
        run_dir=args.run_dir or tempfile.mkdtemp(prefix="mlless_faas_"),
        n_workers=args.workers,
        total_steps=args.steps,
        n_brokers=args.n_brokers,
        transport=args.transport,
        consistency=args.consistency,
        slack=args.slack,
        wire_impl=args.wire_impl,
        hostperf=args.hostperf,
    )
    wc = PMF_QUICKSTART_CFG
    barrier = ("ISP barrier" if cfg.consistency == "isp"
               else f"SSP slack={cfg.slack}")
    print(f"PMF {wc['n_users']}x{wc['n_movies']} rank {wc['rank']}, "
          f"{args.workers} worker processes, {args.steps} steps, "
          f"{cfg.n_brokers} broker shard(s) over {cfg.transport}, "
          f"{barrier}, ISP v={cfg.isp_v}, codec impl {cfg.wire_impl}"
          f"{', hostperf' if cfg.hostperf else ''} (run dir {cfg.run_dir})")
    res = run_job(cfg)

    hist = res["history"]
    first, last = hist[0]["loss"], hist[-1]["loss"]
    bill = res["bill"]
    print(f"\nsteps completed      {res['steps']}")
    print(f"loss                 {first:.3f} -> {last:.3f} "
          f"(eval RMSE {res['final_eval']:.3f})")
    print(f"pool                 {res['n_workers']} -> {res['final_pool']} "
          f"({len(res['scale_events'])} live scale-in decisions)")
    for ev in res["scale_events"]:
        print(f"  evicted worker {ev['worker']} at step {ev['evict_step']} "
              f"({ev['reason']}, s_delta={ev['s_delta']})")
    print(f"mean sent fraction   "
          f"{sum(r['sent_fraction'] for r in hist) / len(hist):.3f}")
    print(f"mean step time       {res['measured_step_s'] * 1e3:.1f} ms "
          f"(measured, {res['n_invocations']} invocations)")
    if res.get("phase_s_mean"):
        enc = res["phase_s_mean"].get("encode")
        if enc is not None:
            print(f"mean encode phase    {enc * 1e3:.2f} ms "
                  f"(impl {res['wire_impl']})")
    if res.get("hostperf") is not None:
        hp = res["hostperf"]
        print(f"hostperf             tcmalloc={hp['tcmalloc'] or 'absent'} "
              f"xla='{hp['xla_flags']}'")
    print(f"worker-seconds       {bill['worker_seconds']:.1f} "
          f"(per-lifetime, 100 ms quantum)")
    print(f"FaaS bill            ${bill['total']:.6f} "
          f"(workers ${bill['worker_cost']:.6f} + infra "
          f"${bill['infra_cost']:.6f})")

    if args.no_check:
        return 0
    ok = True
    if not last < first:
        print("FAIL: loss did not decrease"); ok = False
    if not res["scale_events"]:
        print("FAIL: the auto-tuner never scaled in"); ok = False
    if res["final_pool"] >= res["n_workers"]:
        print("FAIL: pool did not shrink"); ok = False
    if not (bill["worker_seconds"] > 0 and res["n_invocations"]
            >= args.workers):
        print("FAIL: bill not computed from measured lifetimes"); ok = False
    if res["invariant_max_err"] != 0.0:
        print("FAIL: ISP conservation invariant violated"); ok = False
    if res["dup_mismatches"]:
        print("FAIL: replay divergence detected"); ok = False
    print("\nhealth checks:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
