"""Quickstart: train a small LM with BSP vs ISP, watch the filter save bytes.

    PYTHONPATH=src python examples/quickstart.py

Trains the same 4-layer transformer twice — once bulk-synchronous (every
update exchanged), once under the paper's ISP significance filter — and
prints loss + the fraction of parameters whose updates actually had to be
communicated per step (the paper's Fig. 5 effect in miniature).
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.isp import ISPConfig, communicated_fraction, significance_split
from repro.data.tokens import TokenPipeline
from repro.launch.train import LM_8M
from repro.models.transformer import LM
from repro.optim import apply_updates, clip_by_global_norm

STEPS = 30
BATCH, SEQ = 8, 128


def run(mode: str) -> None:
    cfg = LM_8M
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    optimizer = optim.make("adam", 3e-4)
    opt_state = optimizer.init(params)
    residual = jax.tree.map(jnp.zeros_like, params)
    isp = ISPConfig(v=0.7) if mode == "isp" else None
    pipe = TokenPipeline(cfg.vocab_size, SEQ, BATCH, seed=0)

    @jax.jit
    def step(params, opt_state, residual, batch):
        (loss, _), grads = jax.value_and_grad(lm.train_loss, has_aux=True)(
            params, batch
        )
        grads = clip_by_global_norm(grads, 1.0)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        if isp is None:
            return apply_updates(params, updates), opt_state, residual, loss, 1.0
        v_t = isp.threshold(opt_state.step)
        out = jax.tree.map(
            lambda u, x, r: significance_split(r + u, x, v_t),
            updates, params, residual,
        )
        td = jax.tree.structure(params)
        ls = td.flatten_up_to(out)
        sig = td.unflatten([l[0] for l in ls])
        res = td.unflatten([l[1] for l in ls])
        frac = communicated_fraction(td.unflatten([l[2] for l in ls]))
        return apply_updates(params, sig), opt_state, res, loss, frac

    print(f"--- {mode.upper()} ---")
    for i in range(1, STEPS + 1):
        batch = pipe.next_batch(i)
        params, opt_state, residual, loss, frac = step(
            params, opt_state, residual, batch
        )
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}  "
                  f"sent fraction {float(frac):.3f}")


if __name__ == "__main__":
    run("bsp")
    run("isp")
    print("\nISP trains to comparable loss while communicating a small "
          "fraction of the updates — the paper's core claim.")
